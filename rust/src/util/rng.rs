//! Deterministic pseudo-random number generation and the query-range
//! distributions of the paper (§6.4).
//!
//! The offline environment has no `rand` crate, so this module implements
//! SplitMix64 (seeding) and xoshiro256** (bulk generation) from the
//! reference algorithms, plus Box–Muller normals and the log-normal range
//! distributions used for the Medium/Small query workloads.

/// SplitMix64 step — used to expand a single `u64` seed into the xoshiro
/// state. Reference: Steele, Lea, Flood (2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` (single precision).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `usize` in `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller (one sample; the pair's twin is
    /// discarded to keep the generator stateless across calls).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal sample with the given log-space mean and deviation.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a vector with uniform f32 values in [0,1) — the paper's input
    /// arrays ("randomly generated as floats following a uniform
    /// distribution", §6).
    pub fn uniform_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                v => assert!((5..=8).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments_are_plausible() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median_matches_mu() {
        let mut r = Rng::new(13);
        let mu = (1000f64).ln();
        let mut samples: Vec<f64> = (0..50_001).map(|_| r.lognormal(mu, 0.3)).collect();
        // Same latent NaN-panic pattern as `stats::percentile` had:
        // total_cmp is total over all f64 payloads.
        samples.sort_by(f64::total_cmp);
        let median = samples[25_000];
        assert!((median / 1000.0 - 1.0).abs() < 0.05, "median {median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}

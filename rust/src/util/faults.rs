//! Deterministic fault injection for the serving stack (`serve
//! --inject`, the chaos differential suite, and the nightly chaos
//! soak). A [`FaultPlan`] is a list of rules `site:kind:prob:count`
//! parsed from the CLI; arming it installs the plan in a process-global
//! registry that the instrumented sites poll through [`fire`].
//!
//! Design constraints:
//!
//! - **Compiled in always, zero-cost when empty.** [`fire`] is a single
//!   relaxed atomic load on the disarmed (production) path; the plan
//!   lookup, RNG draw and counter updates only run while a plan is
//!   armed.
//! - **Deterministic replay.** Every rule draws from its own
//!   [`Rng`](crate::util::rng::Rng) stream, seeded from `(seed, rule
//!   index)` — a rule's k-th draw is a pure function of the spec, so a
//!   chaos run with a fixed seed injects the same schedule every time
//!   (up to thread interleaving at sites reached from multiple worker
//!   threads, which only reorders draws within one rule).
//! - **Named sites, checked early.** Rules may only name the sites the
//!   code actually instruments ([`SITES`]) — a typo in an `--inject`
//!   spec fails parsing instead of silently injecting nothing. Sites
//!   prefixed `test.` are always accepted (unit tests exercising the
//!   registry itself without touching production sites).
//!
//! Fault kinds: `panic` unwinds at the site (recovery paths catch it),
//! `delay`/`delayN` sleeps N ms (default 1) — latency injection — and
//! `err` makes [`fire`] return `true`, which err-aware sites translate
//! into their forced-failure path (a staged commit conflict, an aborted
//! re-shard install). `panic` is rejected at `stage.commit`: a fence
//! that dies after earlier update segments of its batch landed could
//! not preserve the differential guarantee — use `err` there.
//!
//! The registry also hosts the recovery counters the `faults` metrics
//! line reports: panics caught by the isolation boundaries
//! ([`note_caught`]) and poisoned locks recovered by
//! [`util::sync`](crate::util::sync) ([`note_lock_recovered`]).

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex as StdMutex;
use std::time::Duration;

/// Every instrumented injection site. Kept in one place so specs can be
/// validated at parse time and the docs stay honest.
pub const SITES: &[&str] = &[
    // Background epoch builds (engine.rs builder thread).
    "build.statics",
    "build.reshard",
    // Forced-abort point of a re-shard install (err kind).
    "reshard.install",
    // Staged-update prepare (server.rs staging lane thread).
    "stage.prepare",
    // Per-block replacement build (sharded.rs StagedUpdateSpec::build).
    "stage.build",
    // Fence commit of a staged batch (err = forced conflict).
    "stage.commit",
    // Per-chunk worker closures (util/pool.rs spawned workers).
    "pool.worker",
    // Batcher hand-off (next_batch, serving thread, pre-execution).
    "batcher.handoff",
    // Multi-tenant executor, per claimed batch (coordinator/tenants.rs):
    // fires inside the batch backstop, so a panic rejects exactly that
    // tenant's batch with Failed and touches no other tenant.
    "tenant.exec",
];

/// What an armed rule does when its probability draw hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwind at the site (the panic-isolation boundaries catch it).
    Panic,
    /// Sleep this many milliseconds (latency injection).
    Delay(u64),
    /// Make [`fire`] return `true` — the site's forced-error path.
    Error,
}

/// One parsed `site:kind:prob:count` rule with its private RNG stream.
#[derive(Clone, Debug)]
struct FaultRule {
    site: String,
    kind: FaultKind,
    prob: f64,
    /// Remaining fires; `u64::MAX` = unlimited (`count` of 0).
    remaining: u64,
    rng: Rng,
}

/// A parsed, seeded fault schedule (comma-separated rules).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a comma-separated spec: `site:kind:prob:count[,...]`.
    /// `kind` is `panic`, `err`, `delay` or `delayN` (N ms); `prob` in
    /// (0, 1]; `count` caps the number of fires (0 = unlimited).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for (idx, part) in spec.split(',').map(str::trim).filter(|p| !p.is_empty()).enumerate() {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() != 4 {
                return Err(format!("rule '{part}': expected site:kind:prob:count"));
            }
            let site = fields[0].to_string();
            if !SITES.contains(&site.as_str()) && !site.starts_with("test.") {
                return Err(format!("rule '{part}': unknown site '{site}' (see faults::SITES)"));
            }
            let kind = match fields[1] {
                "panic" => FaultKind::Panic,
                "err" | "error" => FaultKind::Error,
                "delay" => FaultKind::Delay(1),
                d if d.starts_with("delay") => {
                    let ms: u64 = d[5..]
                        .parse()
                        .map_err(|_| format!("rule '{part}': bad delay '{d}'"))?;
                    FaultKind::Delay(ms)
                }
                k => return Err(format!("rule '{part}': unknown kind '{k}'")),
            };
            if kind == FaultKind::Panic && site == "stage.commit" {
                return Err(format!(
                    "rule '{part}': panic at stage.commit would lose a half-applied batch; \
                     use err (forced conflict) instead"
                ));
            }
            let prob: f64 = fields[2]
                .parse()
                .ok()
                .filter(|p| *p > 0.0 && *p <= 1.0)
                .ok_or_else(|| format!("rule '{part}': prob must be in (0, 1]"))?;
            let count: u64 =
                fields[3].parse().map_err(|_| format!("rule '{part}': bad count"))?;
            rules.push(FaultRule {
                site,
                kind,
                prob,
                remaining: if count == 0 { u64::MAX } else { count },
                rng: Rng::new(seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(idx as u64 + 1))),
            });
        }
        Ok(FaultPlan { rules })
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Draw against every rule for `site`; first hit wins.
    fn check(&mut self, site: &str) -> Option<FaultKind> {
        for rule in self.rules.iter_mut() {
            if rule.site != site || rule.remaining == 0 {
                continue;
            }
            if rule.rng.f64() < rule.prob {
                if rule.remaining != u64::MAX {
                    rule.remaining -= 1;
                }
                return Some(rule.kind);
            }
        }
        None
    }
}

// Process-global registry. ARMED is the only thing the production path
// touches; PLAN and the counters live behind it.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: StdMutex<Option<FaultPlan>> = StdMutex::new(None);
static INJECTED_PANICS: AtomicU64 = AtomicU64::new(0);
static INJECTED_DELAYS: AtomicU64 = AtomicU64::new(0);
static INJECTED_ERRORS: AtomicU64 = AtomicU64::new(0);
static CAUGHT: AtomicU64 = AtomicU64::new(0);
static LOCK_RECOVERED: AtomicU64 = AtomicU64::new(0);

/// Install a plan and reset the injection counters. An empty plan
/// leaves the registry disarmed (zero-cost).
pub fn arm(plan: FaultPlan) {
    for c in [&INJECTED_PANICS, &INJECTED_DELAYS, &INJECTED_ERRORS, &CAUGHT, &LOCK_RECOVERED] {
        c.store(0, Ordering::Relaxed);
    }
    let armed = !plan.is_empty();
    *PLAN.lock().unwrap_or_else(|p| p.into_inner()) = if armed { Some(plan) } else { None };
    ARMED.store(armed, Ordering::Release);
}

/// Disarm the registry (counters are kept for post-run reporting).
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *PLAN.lock().unwrap_or_else(|p| p.into_inner()) = None;
}

/// RAII arming for tests: disarms on drop even if the test panics.
pub struct ArmGuard(());

pub fn arm_guard(plan: FaultPlan) -> ArmGuard {
    arm(plan);
    ArmGuard(())
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        disarm();
    }
}

/// Poll the registry at a named site. Returns `true` iff an `err` fault
/// fired (the caller's forced-failure path); a `panic` fault unwinds
/// from here, a `delay` fault sleeps and returns `false`. Disarmed:
/// one relaxed load, nothing else.
#[inline]
pub fn fire(site: &str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    fire_armed(site)
}

#[cold]
fn fire_armed(site: &str) -> bool {
    // The kind is extracted and the guard dropped *before* any panic so
    // the plan mutex can never be poisoned by its own injection.
    let kind = {
        let mut plan = PLAN.lock().unwrap_or_else(|p| p.into_inner());
        plan.as_mut().and_then(|p| p.check(site))
    };
    match kind {
        None => false,
        Some(FaultKind::Panic) => {
            INJECTED_PANICS.fetch_add(1, Ordering::Relaxed);
            panic!("injected fault: panic at {site}");
        }
        Some(FaultKind::Delay(ms)) => {
            INJECTED_DELAYS.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
            false
        }
        Some(FaultKind::Error) => {
            INJECTED_ERRORS.fetch_add(1, Ordering::Relaxed);
            true
        }
    }
}

/// A panic-isolation boundary caught an unwind (pool worker retry,
/// stager fallback, builder respawn, serving-loop backstop).
pub fn note_caught() {
    CAUGHT.fetch_add(1, Ordering::Relaxed);
}

/// A poison-recovering lock wrapper recovered a poisoned guard.
pub fn note_lock_recovered() {
    LOCK_RECOVERED.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of the registry counters (the metrics `faults` line).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub injected_panics: u64,
    pub injected_delays: u64,
    pub injected_errors: u64,
    pub caught: u64,
    pub lock_recovered: u64,
}

impl FaultStats {
    pub fn injected(&self) -> u64 {
        self.injected_panics + self.injected_delays + self.injected_errors
    }
}

pub fn stats() -> FaultStats {
    FaultStats {
        injected_panics: INJECTED_PANICS.load(Ordering::Relaxed),
        injected_delays: INJECTED_DELAYS.load(Ordering::Relaxed),
        injected_errors: INJECTED_ERRORS.load(Ordering::Relaxed),
        caught: CAUGHT.load(Ordering::Relaxed),
        lock_recovered: LOCK_RECOVERED.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_multi_rule_specs() {
        let p = FaultPlan::parse(
            "stage.prepare:panic:0.5:3, pool.worker:delay2:1.0:0 ,reshard.install:err:0.25:1",
            7,
        )
        .unwrap();
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0].kind, FaultKind::Panic);
        assert_eq!(p.rules[0].remaining, 3);
        assert_eq!(p.rules[1].kind, FaultKind::Delay(2));
        assert_eq!(p.rules[1].remaining, u64::MAX, "count 0 = unlimited");
        assert_eq!(p.rules[2].kind, FaultKind::Error);
        assert!(FaultPlan::parse("", 7).unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "nope.site:panic:0.5:1",       // unknown site
            "stage.prepare:explode:0.5:1", // unknown kind
            "stage.prepare:panic:1.5:1",   // prob out of range
            "stage.prepare:panic:0:1",     // prob must be > 0
            "stage.prepare:panic:0.5",     // missing field
            "stage.prepare:delayx:0.5:1",  // bad delay
            "stage.commit:panic:0.5:1",    // mid-fence panic forbidden
        ] {
            assert!(FaultPlan::parse(bad, 1).is_err(), "{bad}");
        }
        // err at stage.commit is the supported forced-conflict form.
        assert!(FaultPlan::parse("stage.commit:err:0.5:1", 1).is_ok());
    }

    #[test]
    fn draws_are_deterministic_per_seed_and_count_limited() {
        let draw = |seed: u64| {
            let mut p = FaultPlan::parse("test.site:err:0.5:4", seed).unwrap();
            (0..64).map(|_| p.check("test.site").is_some()).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42), "same seed, same schedule");
        assert_ne!(draw(42), draw(43), "seed changes the schedule");
        assert_eq!(draw(42).iter().filter(|&&hit| hit).count(), 4, "count caps total fires");
        // Draws at other sites pull nothing from this rule's stream.
        let mut p = FaultPlan::parse("test.site:err:1.0:1", 1).unwrap();
        assert!(p.check("test.other").is_none());
        assert!(p.check("test.site").is_some());
    }

    #[test]
    fn global_registry_fires_counts_and_disarms() {
        // Serialized against other tests of the *global* registry by
        // using only `test.`-prefixed sites no other code polls.
        let _g = arm_guard(FaultPlan::parse("test.reg:err:1.0:2,test.lat:delay:1.0:1", 3).unwrap());
        assert!(fire("test.reg"));
        assert!(fire("test.reg"));
        assert!(!fire("test.reg"), "count exhausted");
        assert!(!fire("test.lat"), "delay returns false");
        assert!(!fire("test.unarmed"));
        let s = stats();
        assert_eq!(s.injected_errors, 2);
        assert_eq!(s.injected_delays, 1);
        assert_eq!(s.injected(), 3);
        drop(_g);
        assert!(!fire("test.reg"), "disarmed on guard drop");
    }

    #[test]
    fn injected_panic_unwinds_and_is_countable() {
        let _g = arm_guard(FaultPlan::parse("test.boom:panic:1.0:1", 5).unwrap());
        let r = std::panic::catch_unwind(|| fire("test.boom"));
        assert!(r.is_err(), "panic kind unwinds");
        note_caught();
        let s = stats();
        assert_eq!(s.injected_panics, 1);
        assert!(s.caught >= 1);
        assert!(!fire("test.boom"), "single-shot");
    }
}

//! Minimal clap-free command-line parsing (the offline environment has no
//! `clap`). Supports `binary <subcommand> [--key value] [--flag]` with
//! typed accessors, defaults, and `--help` text generation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed command line: a subcommand, `--key value` options, bare `--flag`
/// switches and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Error produced by typed accessors.
#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Parse(String, String, &'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(k) => write!(f, "missing required option --{k}"),
            CliError::Parse(k, v, ty) => {
                write!(f, "option --{k}={v} is not a valid {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value` form
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                    continue;
                }
                // `--key value` form when the next token is not an option;
                // otherwise a bare flag.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.opts.insert(name.to_string(), v);
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Required string option.
    pub fn str_req(&self, key: &str) -> Result<String, CliError> {
        self.opt(key)
            .map(|s| s.to_string())
            .ok_or_else(|| CliError::Missing(key.to_string()))
    }

    /// Typed option with default. Accepts `2^k` and `_`-separated digits
    /// for integer types via [`parse_scaled`].
    pub fn get_or<T: FromCliStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => {
                T::from_cli_str(s).ok_or_else(|| CliError::Parse(key.into(), s.into(), T::NAME))
            }
        }
    }

    /// Bare `--flag` presence.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// List of values from a comma-separated option, e.g. `--ns 2^10,2^12`.
    pub fn list_or<T: FromCliStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.opt(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|part| {
                    T::from_cli_str(part.trim())
                        .ok_or_else(|| CliError::Parse(key.into(), part.into(), T::NAME))
                })
                .collect(),
        }
    }
}

/// Parse integers allowing `2^k` power notation and `_` digit separators —
/// convenient for paper-scale sizes (`--n 2^26`).
pub fn parse_scaled(s: &str) -> Option<u64> {
    let s = s.replace('_', "");
    if let Some(exp) = s.strip_prefix("2^") {
        let e: u32 = exp.parse().ok()?;
        return 1u64.checked_shl(e);
    }
    if let Some(mantissa) = s.strip_suffix(['M', 'm']) {
        let f: f64 = mantissa.parse().ok()?;
        return Some((f * 1e6) as u64);
    }
    if let Some(mantissa) = s.strip_suffix(['K', 'k']) {
        let f: f64 = mantissa.parse().ok()?;
        return Some((f * 1e3) as u64);
    }
    s.parse().ok()
}

/// Conversion trait for typed CLI accessors.
pub trait FromCliStr: Sized {
    const NAME: &'static str;
    fn from_cli_str(s: &str) -> Option<Self>;
}

macro_rules! impl_from_cli_int {
    ($($t:ty),*) => {$(
        impl FromCliStr for $t {
            const NAME: &'static str = stringify!($t);
            fn from_cli_str(s: &str) -> Option<Self> {
                parse_scaled(s).and_then(|v| <$t>::try_from(v).ok())
            }
        }
    )*};
}
impl_from_cli_int!(u64, u32, usize, i64);

impl FromCliStr for f64 {
    const NAME: &'static str = "f64";
    fn from_cli_str(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl FromCliStr for String {
    const NAME: &'static str = "string";
    fn from_cli_str(s: &str) -> Option<Self> {
        Some(s.to_string())
    }
}

/// Help-text builder so every subcommand prints consistent usage.
pub struct Help {
    name: &'static str,
    about: &'static str,
    entries: Vec<(String, String)>,
}

impl Help {
    pub fn new(name: &'static str, about: &'static str) -> Help {
        Help { name, about, entries: Vec::new() }
    }

    pub fn opt(mut self, key: &str, desc: &str) -> Help {
        self.entries.push((format!("--{key}"), desc.to_string()));
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let width = self.entries.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        for (k, d) in &self.entries {
            let _ = writeln!(s, "  {k:<width$}  {d}");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["bench", "--n", "1024", "--dist=small", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get_or("n", 0usize).unwrap(), 1024);
        assert_eq!(a.str_or("dist", "large"), "small");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn power_notation_and_suffixes() {
        assert_eq!(parse_scaled("2^20"), Some(1 << 20));
        assert_eq!(parse_scaled("10M"), Some(10_000_000));
        assert_eq!(parse_scaled("64k"), Some(64_000));
        assert_eq!(parse_scaled("1_000_000"), Some(1_000_000));
        assert_eq!(parse_scaled("nope"), None);
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_or("n", 0usize).is_err());
        assert!(a.str_req("missing").is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--ns", "2^10,2^12,100"]);
        assert_eq!(a.list_or::<u64>("ns", &[]).unwrap(), vec![1024, 4096, 100]);
        let b = parse(&["x"]);
        assert_eq!(b.list_or::<u64>("ns", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "file1", "file2", "--k", "v"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn help_renders_all_entries() {
        let h = Help::new("bench", "run benches").opt("n", "array size").opt("q", "queries");
        let text = h.render();
        assert!(text.contains("--n") && text.contains("--q") && text.contains("run benches"));
    }
}

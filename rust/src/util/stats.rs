//! Statistics utilities: online mean/variance (Welford), percentiles, and
//! a log-bucketed latency histogram for the coordinator's metrics — the
//! environment provides no `criterion`/`hdrhistogram`, so the bench
//! harness builds on these.

/// Online mean / variance / min / max accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Relative standard error of the mean — used by the bench harness's
    /// adaptive stopping rule.
    pub fn rel_stderr(&self) -> f64 {
        if self.n < 2 || self.mean == 0.0 {
            f64::INFINITY
        } else {
            (self.stddev() / (self.n as f64).sqrt()) / self.mean.abs()
        }
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile (nearest-rank on a copy; fine for bench-sized samples).
///
/// Sorts with [`f64::total_cmp`]: a NaN sample (e.g. a 0/0 ratio from an
/// unmeasured bench column) sorts to the top instead of panicking the
/// whole report inside `partial_cmp().unwrap()`.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank]
}

/// Log2-bucketed histogram for latencies in nanoseconds: bucket `i` covers
/// `[2^i, 2^(i+1))` ns. Constant memory, lock-free-mergeable, good enough
/// for p50/p99 at coordinator scale.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; 64], count: 0, sum_ns: 0 }
    }

    pub fn record(&mut self, ns: u64) {
        let b = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_ns += ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the given quantile (0..=1).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human-readable byte count (MB as in the paper's Table 2: 1 MB = 2^20 B).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.2} MB", bytes as f64 / (1u64 << 20) as f64)
}

/// Geometric mean — used for speedup aggregation across sizes.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of the set above is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut all = OnlineStats::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` panicked on any NaN in
        // the sample (a 0/0 speedup ratio was enough to kill a whole
        // bench report). total_cmp sorts NaN above +inf, so the finite
        // percentiles stay meaningful.
        let v = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        // nearest-rank on the sorted [1, 2, 3, NaN]: round(1.5) = 2.
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert!(percentile(&v, 100.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 300, 400, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        let p50 = h.quantile_ns(0.5);
        assert!(p50 >= 256 && p50 <= 512, "p50 bucket bound {p50}");
        let p99 = h.quantile_ns(0.99);
        assert!(p99 >= 100_000, "p99 {p99}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(100);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_ns() - 550.0).abs() < 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_mb(1 << 20), "1.00 MB");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}

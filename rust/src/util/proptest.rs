//! Mini property-based testing harness (the offline environment has no
//! `proptest`). Runs a property over many random inputs with a fixed base
//! seed (reproducible), reports the failing seed, and on failure attempts
//! a simple size-reduction pass ("shrinking-lite") for slice inputs.
//!
//! Usage:
//! ```ignore
//! check("sorted arrays answer rmq", 200, |rng| {
//!     let xs = gen::f32_array(rng, 1..=512);
//!     // ... assert property, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Base seed for all property tests; override with `RTXRMQ_PROP_SEED` to
/// replay a CI failure locally.
pub fn base_seed() -> u64 {
    std::env::var("RTXRMQ_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Number of cases multiplier (`RTXRMQ_PROP_CASES_MULT`), for soak runs.
fn cases_mult() -> u64 {
    std::env::var("RTXRMQ_PROP_CASES_MULT").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// Run `property` for `cases` random cases. Each case gets an independent
/// RNG derived from (base seed, case index) so failures replay in
/// isolation. Panics with the case seed on the first failure.
pub fn check<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let cases = cases * cases_mult();
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed={seed:#x}): {msg}\n\
                 replay: RTXRMQ_PROP_SEED={base} (case index {case})"
            );
        }
    }
}

/// Generators for common inputs.
pub mod gen {
    use super::Rng;
    use std::ops::RangeInclusive;

    /// Array length drawn log-uniformly from the range (small sizes are
    /// over-sampled — that's where edge cases live).
    pub fn len_in(rng: &mut Rng, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start() as f64, *range.end() as f64);
        debug_assert!(lo >= 1.0 && hi >= lo);
        let x = (lo.ln() + rng.f64() * (hi.ln() - lo.ln())).exp();
        (x as usize).clamp(*range.start(), *range.end())
    }

    /// Uniform f32 array in [0,1) — the paper's input distribution.
    pub fn f32_array(rng: &mut Rng, len: RangeInclusive<usize>) -> Vec<f32> {
        let n = len_in(rng, len);
        rng.uniform_f32_vec(n)
    }

    /// Integer-valued f32 array with many duplicates — exercises the
    /// leftmost-tie-break rule.
    pub fn dup_array(rng: &mut Rng, len: RangeInclusive<usize>, distinct: usize) -> Vec<f32> {
        let n = len_in(rng, len);
        (0..n).map(|_| rng.below(distinct as u64) as f32).collect()
    }

    /// Adversarial array shapes (sorted / reversed / constant / sawtooth /
    /// organ-pipe), chosen at random.
    pub fn adversarial_array(rng: &mut Rng, len: RangeInclusive<usize>) -> Vec<f32> {
        let n = len_in(rng, len);
        match rng.below(5) {
            0 => (0..n).map(|i| i as f32).collect(),
            1 => (0..n).map(|i| (n - i) as f32).collect(),
            2 => vec![1.0; n],
            3 => (0..n).map(|i| (i % 16) as f32).collect(),
            _ => (0..n).map(|i| (i.min(n - 1 - i)) as f32).collect(),
        }
    }

    /// A valid (l, r) query over an array of length `n`.
    pub fn query(rng: &mut Rng, n: usize) -> (usize, usize) {
        let l = rng.range(0, n - 1);
        let r = rng.range(l, n - 1);
        (l, r)
    }

    /// A batch of queries.
    pub fn queries(rng: &mut Rng, n: usize, count: usize) -> Vec<(usize, usize)> {
        (0..count).map(|_| query(rng, n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0u64);
        check("always ok", 50, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert_eq!(counter.get() % 50, 0); // exact multiple (cases_mult)
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let v = gen::f32_array(&mut rng, 1..=64);
            assert!((1..=64).contains(&v.len()));
            let (l, r) = gen::query(&mut rng, v.len());
            assert!(l <= r && r < v.len());
        }
    }

    #[test]
    fn dup_array_has_duplicates() {
        let mut rng = Rng::new(2);
        let v = gen::dup_array(&mut rng, 100..=100, 3);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.len() == 100);
    }

    #[test]
    fn adversarial_shapes_cover() {
        let mut rng = Rng::new(3);
        let mut constant_seen = false;
        for _ in 0..100 {
            let v = gen::adversarial_array(&mut rng, 8..=8);
            if v.iter().all(|&x| x == v[0]) {
                constant_seen = true;
            }
        }
        assert!(constant_seen);
    }
}

//! Poison-recovering `Mutex` / `RwLock` wrappers. The serving stack
//! isolates panics with `catch_unwind` at thread boundaries, but a
//! panic while a guard is held still poisons a std lock — and every
//! later `.lock().unwrap()` would then wedge the serving loop forever.
//! These wrappers recover the guard instead (`PoisonError::into_inner`)
//! and count the recovery in the fault registry, so one dead worker can
//! never take the whole coordinator down.
//!
//! Recovering a poisoned guard is only sound because every structure
//! guarded by these locks is repaired (or rebuilt from source values)
//! by the same `catch_unwind` boundary that caught the panic — see the
//! "Failure model" note in `rmq/mod.rs`.

use crate::util::faults;
use std::sync::{
    Mutex as StdMutex, MutexGuard, RwLock as StdRwLock, RwLockReadGuard, RwLockWriteGuard,
    TryLockError,
};

/// `std::sync::Mutex` whose `lock()` returns the guard directly,
/// recovering (and counting) poison instead of propagating it.
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized>(StdMutex<T>);

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Self {
        Mutex(StdMutex::new(value))
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.0.lock().unwrap_or_else(|p| {
            faults::note_lock_recovered();
            p.into_inner()
        })
    }

    pub fn try_lock(&self) -> Option<MutexGuard<'_, T>> {
        match self.0.try_lock() {
            Ok(g) => Some(g),
            Err(TryLockError::Poisoned(p)) => {
                faults::note_lock_recovered();
                Some(p.into_inner())
            }
            Err(TryLockError::WouldBlock) => None,
        }
    }
}

/// `std::sync::RwLock` with the same poison-recovering contract.
#[derive(Debug, Default)]
pub struct RwLock<T: ?Sized>(StdRwLock<T>);

impl<T> RwLock<T> {
    pub const fn new(value: T) -> Self {
        RwLock(StdRwLock::new(value))
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        self.0.read().unwrap_or_else(|p| {
            faults::note_lock_recovered();
            p.into_inner()
        })
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        self.0.write().unwrap_or_else(|p| {
            faults::note_lock_recovered();
            p.into_inner()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn mutex_survives_panic_while_held() {
        let m = Mutex::new(vec![1, 2, 3]);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock();
            panic!("die with the guard held");
        }));
        assert!(r.is_err());
        // A std mutex would now be poisoned; the wrapper recovers.
        assert_eq!(*m.lock(), vec![1, 2, 3]);
        assert!(m.try_lock().is_some());
    }

    #[test]
    fn rwlock_survives_panic_while_write_held() {
        let l = RwLock::new(7u64);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let mut g = l.write();
            *g = 8;
            panic!("die mid-write");
        }));
        assert!(r.is_err());
        assert_eq!(*l.read(), 8, "writes before the panic are visible");
        *l.write() = 9;
        assert_eq!(*l.read(), 9);
    }

    #[test]
    fn try_lock_contends_without_poison() {
        let m = Mutex::new(0u8);
        let g = m.lock();
        assert!(m.try_lock().is_none(), "held elsewhere, not poisoned");
        drop(g);
        assert!(m.try_lock().is_some());
    }
}

//! RTXRMQ — reproduction of *Accelerating Range Minimum Queries with Ray
//! Tracing Cores* (Meneses, Navarro, Ferrada, Quezada; CS.DC 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! Layer map (see DESIGN.md):
//! - **L3** — this crate: RT-core simulator substrate, RMQ solvers
//!   (RTXRMQ, HRMQ, LCA, exhaustive), serving coordinator, cost/energy
//!   models, bench harness.
//! - **L2/L1** — `python/compile`: JAX block-RMQ graph calling Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`, executed from Rust via
//!   PJRT (`runtime`). Python never runs on the request path.

pub mod bench_harness;
pub mod bvh;
pub mod coordinator;
pub mod geometry;
pub mod model;
pub mod rmq;
pub mod rtcore;
pub mod runtime;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

//! EXHAUSTIVE baseline (paper §6.1): "each thread handles one query and
//! searches the minimum from left to right in the (l, r) range". This is
//! the CPU form of the paper's reference CUDA kernel; the GPU form is the
//! L1 Pallas kernel executed through the PJRT runtime
//! (`coordinator::engines::XlaEngine`). No data structure is required
//! (Table 2 lists it as structure-free).

use super::RmqSolver;

/// Brute-force scan solver.
pub struct Exhaustive {
    xs: Vec<f32>,
}

impl Exhaustive {
    pub fn new(xs: &[f32]) -> Exhaustive {
        assert!(!xs.is_empty(), "empty array");
        Exhaustive { xs: xs.to_vec() }
    }

    pub fn values(&self) -> &[f32] {
        &self.xs
    }
}

impl RmqSolver for Exhaustive {
    fn name(&self) -> &'static str {
        "EXHAUSTIVE"
    }

    #[inline]
    fn rmq(&self, l: u32, r: u32) -> u32 {
        let xs = &self.xs;
        debug_assert!(l <= r && (r as usize) < xs.len());
        let mut best = l as usize;
        let mut best_v = xs[best];
        // Strict `<` keeps the leftmost occurrence on ties.
        for k in (l as usize + 1)..=(r as usize) {
            let v = xs[k];
            if v < best_v {
                best = k;
                best_v = v;
            }
        }
        best as u32
    }

    fn memory_bytes(&self) -> usize {
        // Table 2 lists EXHAUSTIVE as structure-free, but this solver
        // *owns* the copy it scans — resident accounting counts every
        // owned allocation (see the trait doc).
        self.xs.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::SparseTable;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let ex = Exhaustive::new(&xs);
        assert_eq!(ex.rmq(2, 6), 5);
        assert_eq!(ex.rmq(0, 0), 0);
    }

    #[test]
    fn ties_leftmost() {
        let xs = [2.0, 1.0, 1.0, 1.0];
        let ex = Exhaustive::new(&xs);
        assert_eq!(ex.rmq(0, 3), 1);
        assert_eq!(ex.rmq(2, 3), 2);
    }

    #[test]
    fn batch_matches_oracle() {
        check("exhaustive batch vs oracle", 60, |rng| {
            let xs = gen::f32_array(rng, 1..=1024);
            let queries = gen::queries(rng, xs.len(), 64)
                .into_iter()
                .map(|(l, r)| (l as u32, r as u32))
                .collect::<Vec<_>>();
            let ex = Exhaustive::new(&xs);
            let st = SparseTable::new(&xs);
            let got = ex.batch(&queries, 2);
            let want = st.batch(&queries, 1);
            if got != want {
                return Err("batch mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_exactly_the_owned_copy() {
        // Structure-free in the Table 2 sense: nothing beyond the input
        // copy the solver owns.
        assert_eq!(Exhaustive::new(&[1.0]).memory_bytes(), 4);
        assert_eq!(Exhaustive::new(&[1.0; 100]).memory_bytes(), 400);
    }
}

//! HRMQ — succinct balanced-parentheses RMQ in the style of Ferrada &
//! Navarro 2017 ("Improved range minimum queries", the paper's CPU state
//! of the art, §6.1).
//!
//! Encoding: the Cartesian tree is *not* materialised. While scanning the
//! array with the rightmost-spine stack we emit `)` for every pop and `(`
//! for every push, closing all remaining opens at the end — the classical
//! 2n-bit parentheses encoding where the i-th `(` corresponds to array
//! position i (pushes happen in array order).
//!
//! Query: let `exc[p]` be the paren excess after position `p`
//! (`exc[-1] = 0`), and `open(i)` the position of the i-th `(`. Then
//!
//! ```text
//! RMQ(l, r) = rank_open(w + 1),
//!    w = rightmost argmin of exc over [open(l) - 1, open(r) - 1]
//! ```
//!
//! *Why*: the excess at `p` equals the stack depth at that moment; the
//! lowest depth inside the window is reached immediately before pushing
//! the range minimum (everything above it has popped), and — because pops
//! are strict — later returns to the same depth correspond to smaller
//! elements, so the **rightmost** minimum-excess position identifies the
//! leftmost minimum *value* of the range. The char at `w+1` is that
//! element's `(`.
//!
//! The excess structure is a two-level rmM-style hierarchy: per 64-bit
//! word a `rank` sample and an 8-bit min-excess delta; per superblock
//! (32 words) a min; a sparse table over superblock minima for O(1) range
//! minima, with O(log) binary-search location of the rightmost match.
//! Space ≈ 2n bits for the parens + ~3.5 bits/elem of directories
//! (paper reports ~2.1n bits; the delta is our coarser rank sampling,
//! counted honestly in `memory_bytes`).

use super::RmqSolver;

const WORD_BITS: usize = 64;
/// Words per superblock.
const SB_WORDS: usize = 32;
/// One select sample every this many `(`s.
const SELECT_SAMPLE: usize = 512;

/// Succinct-style balanced-parentheses RMQ.
pub struct Hrmq {
    /// Parentheses: bit = 1 for `(`, 0 for `)`. Position p is bit p%64 of
    /// word p/64. Length is exactly 2n bits.
    words: Vec<u64>,
    /// Number of positions (2n).
    len: usize,
    n: usize,
    /// rank1 before the start of each word (+ total sentinel).
    rank: Vec<u32>,
    /// (min excess within word) − (excess at word start); in [−64, 0].
    min_delta: Vec<i8>,
    /// Min excess per superblock.
    sb_min: Vec<i32>,
    /// Sparse table of min values over `sb_min`: st[k][s] = min over
    /// superblocks [s, s + 2^(k+1)).
    sb_st: Vec<Vec<i32>>,
    /// Word index containing the (SELECT_SAMPLE·k + 1)-th `(`.
    select_sample: Vec<u32>,
}

impl Hrmq {
    pub fn new(xs: &[f32]) -> Hrmq {
        let n = xs.len();
        assert!(n > 0, "empty array");
        let len = 2 * n;
        let mut words = vec![0u64; len.div_ceil(WORD_BITS)];
        // Emit the parentheses with the Cartesian stack (strict pops keep
        // leftmost ties as ancestors).
        {
            let mut pos = 0usize;
            let mut set = |p: usize| {
                words[p / WORD_BITS] |= 1u64 << (p % WORD_BITS);
            };
            let mut stack: Vec<f32> = Vec::with_capacity(64);
            for &x in xs {
                while let Some(&top) = stack.last() {
                    if top > x {
                        stack.pop();
                        pos += 1; // ')' = 0 bit, nothing to set
                    } else {
                        break;
                    }
                }
                set(pos);
                pos += 1;
                stack.push(x);
            }
            pos += stack.len(); // trailing ')'s
            debug_assert_eq!(pos, len);
        }

        // Directories.
        let nwords = words.len();
        let mut rank = Vec::with_capacity(nwords + 1);
        let mut min_delta = Vec::with_capacity(nwords);
        let mut sb_min = Vec::with_capacity(nwords.div_ceil(SB_WORDS));
        let mut select_sample = Vec::new();
        let mut ones = 0u32;
        let mut excess = 0i32;
        let mut cur_sb_min = i32::MAX;
        for (w, &word) in words.iter().enumerate() {
            rank.push(ones);
            // Select samples: does a sampled `(` land in this word?
            let wc = word.count_ones();
            let lo = ones as usize; // ones before this word
            let hi = lo + wc as usize;
            // samples are the (SELECT_SAMPLE*k + 1)-th ones (1-based)
            let mut k = lo / SELECT_SAMPLE + usize::from(lo % SELECT_SAMPLE != 0);
            while SELECT_SAMPLE * k < hi {
                if SELECT_SAMPLE * k >= lo {
                    debug_assert_eq!(select_sample.len(), k);
                    select_sample.push(w as u32);
                }
                k += 1;
            }
            ones += wc;
            // Min excess within this word. The last (possibly partial)
            // word: positions >= len are absent; they are 0-bits, which
            // would only *lower* the min, so clamp the scan length.
            let valid = if (w + 1) * WORD_BITS <= len { WORD_BITS } else { len - w * WORD_BITS };
            let start_excess = excess;
            let mut min_in = i32::MAX;
            for b in 0..valid {
                excess += if (word >> b) & 1 == 1 { 1 } else { -1 };
                min_in = min_in.min(excess);
            }
            min_delta.push((min_in - start_excess) as i8);
            cur_sb_min = cur_sb_min.min(min_in);
            if (w + 1) % SB_WORDS == 0 || w + 1 == nwords {
                sb_min.push(cur_sb_min);
                cur_sb_min = i32::MAX;
            }
        }
        rank.push(ones);
        debug_assert_eq!(ones as usize, n);
        debug_assert_eq!(excess, 0);

        // Sparse table of min values over superblocks.
        let nsb = sb_min.len();
        let max_k =
            if nsb <= 1 { 0 } else { usize::BITS as usize - 1 - nsb.leading_zeros() as usize };
        let mut sb_st: Vec<Vec<i32>> = Vec::with_capacity(max_k);
        for k in 1..=max_k {
            let width = 1usize << k;
            let half = width / 2;
            let level = {
                let prev = sb_st.last();
                (0..nsb + 1 - width)
                    .map(|i| {
                        let a = prev.map_or(sb_min[i], |p| p[i]);
                        let b = prev.map_or(sb_min[i + half], |p| p[i + half]);
                        a.min(b)
                    })
                    .collect()
            };
            sb_st.push(level);
        }

        Hrmq { words, len, n, rank, min_delta, sb_min, sb_st, select_sample }
    }

    /// Number of `(` in positions `[0, p)`.
    #[inline]
    fn rank1(&self, p: usize) -> usize {
        let (w, b) = (p / WORD_BITS, p % WORD_BITS);
        let partial =
            if b == 0 { 0 } else { (self.words[w] & ((1u64 << b) - 1)).count_ones() as usize };
        self.rank[w] as usize + partial
    }

    /// Position of the i-th `(` (0-based i).
    #[inline]
    fn select_open(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        let target = i + 1; // 1-based count
        let mut w = self.select_sample[i / SELECT_SAMPLE] as usize;
        // Walk forward to the word containing the target one.
        while (self.rank[w + 1] as usize) < target {
            w += 1;
        }
        let within = (target - self.rank[w] as usize - 1) as u32;
        w * WORD_BITS + crate::util::bits::select_in_word(self.words[w], within) as usize
    }

    /// Excess after position p (`p < len`).
    #[inline]
    fn excess_at(&self, p: usize) -> i32 {
        2 * self.rank1(p + 1) as i32 - (p as i32 + 1)
    }

    /// Excess at the start of word w.
    #[inline]
    fn word_start_excess(&self, w: usize) -> i32 {
        2 * self.rank[w] as i32 - (w * WORD_BITS) as i32
    }

    /// Min excess over the whole word w.
    #[inline]
    fn word_min(&self, w: usize) -> i32 {
        self.word_start_excess(w) + self.min_delta[w] as i32
    }

    /// Scan positions [p0, p1] (within one word), returning (min excess,
    /// rightmost argmin).
    fn scan_word(&self, p0: usize, p1: usize) -> (i32, usize) {
        debug_assert!(p0 / WORD_BITS == p1 / WORD_BITS && p0 <= p1);
        let w = p0 / WORD_BITS;
        let word = self.words[w];
        let mut e = if p0 % WORD_BITS == 0 { self.word_start_excess(w) } else { self.excess_at(p0 - 1) };
        let mut min = i32::MAX;
        let mut pos = p0;
        for p in p0..=p1 {
            e += if (word >> (p % WORD_BITS)) & 1 == 1 { 1 } else { -1 };
            if e <= min {
                min = e;
                pos = p;
            }
        }
        (min, pos)
    }

    /// Min over superblocks [s0, s1] via the sparse table.
    fn sb_range_min(&self, s0: usize, s1: usize) -> i32 {
        debug_assert!(s0 <= s1);
        let span = s1 - s0 + 1;
        if span == 1 {
            return self.sb_min[s0];
        }
        let k = usize::BITS as usize - 1 - span.leading_zeros() as usize;
        let level = &self.sb_st[k - 1];
        level[s0].min(level[s1 + 1 - (1 << k)])
    }

    /// Min excess over positions [lo, hi] (`lo ≥ 0`), full-resolution.
    fn range_min_excess(&self, lo: usize, hi: usize) -> i32 {
        let (w0, w1) = (lo / WORD_BITS, hi / WORD_BITS);
        if w0 == w1 {
            return self.scan_word(lo, hi).0;
        }
        let mut m = self.scan_word(lo, (w0 + 1) * WORD_BITS - 1).0;
        m = m.min(self.scan_word(w1 * WORD_BITS, hi).0);
        // Full words (w0, w1) exclusive.
        let (a, b) = (w0 + 1, w1); // words [a, b)
        if a < b {
            // Edge words up to superblock boundaries.
            let sb_a = a.div_ceil(SB_WORDS);
            let sb_b = b / SB_WORDS;
            if sb_a <= sb_b && sb_a * SB_WORDS >= a && sb_b * SB_WORDS <= b && sb_a < sb_b {
                for w in a..sb_a * SB_WORDS {
                    m = m.min(self.word_min(w));
                }
                for w in sb_b * SB_WORDS..b {
                    m = m.min(self.word_min(w));
                }
                m = m.min(self.sb_range_min(sb_a, sb_b - 1));
            } else {
                for w in a..b {
                    m = m.min(self.word_min(w));
                }
            }
        }
        m
    }

    /// Rightmost position in [lo, hi] whose excess equals `m` (caller
    /// guarantees one exists).
    fn rightmost_with_excess(&self, lo: usize, hi: usize, m: i32) -> usize {
        let (w0, w1) = (lo / WORD_BITS, hi / WORD_BITS);
        // Last partial word.
        {
            let p0 = if w1 == w0 { lo } else { w1 * WORD_BITS };
            let (wm, wpos) = self.scan_word(p0, hi);
            if wm == m {
                return wpos;
            }
            if w0 == w1 {
                unreachable!("min not found in single-word window");
            }
        }
        // Full words (w0, w1) descending, with superblock skipping.
        let (a, b) = (w0 + 1, w1); // full words in [a, b)
        let mut w = b;
        while w > a {
            // If at a superblock end and the whole superblock is inside
            // [a, b), consult the superblock min to skip 32 words.
            if w % SB_WORDS == 0 {
                let s = w / SB_WORDS - 1;
                if s * SB_WORDS >= a && self.sb_min[s] > m {
                    w = s * SB_WORDS;
                    continue;
                }
            }
            w -= 1;
            if self.word_min(w) == m {
                let (wm, wpos) = self.scan_word(w * WORD_BITS, (w + 1) * WORD_BITS - 1);
                debug_assert_eq!(wm, m);
                return wpos;
            }
        }
        // First partial word.
        let (wm, wpos) = self.scan_word(lo, (w0 + 1) * WORD_BITS - 1);
        debug_assert_eq!(wm, m, "min must be in first partial word");
        let _ = wm;
        wpos
    }

    /// Core operation: rightmost argmin of excess over window positions
    /// `[a, b]` where `a` may be −1 (virtual `exc[-1] = 0`). Returns the
    /// position (−1 possible).
    fn rightmost_min_excess(&self, a: i64, b: i64) -> i64 {
        debug_assert!(b >= a && b >= 0 && (b as usize) < self.len);
        let lo = a.max(0) as usize;
        let hi = b as usize;
        let mut m = self.range_min_excess(lo, hi);
        if a < 0 && 0 < m {
            // Virtual exc[-1] = 0 is the unique minimum.
            return -1;
        }
        if a < 0 {
            m = m.min(0);
        }
        self.rightmost_with_excess(lo, hi, m) as i64
    }

    /// Total parens (2n) — exposed for tests.
    pub fn bp_len(&self) -> usize {
        self.len
    }
}

impl RmqSolver for Hrmq {
    fn name(&self) -> &'static str {
        "HRMQ"
    }

    fn rmq(&self, l: u32, r: u32) -> u32 {
        if l == r {
            return l;
        }
        let x = self.select_open(l as usize);
        let y = self.select_open(r as usize);
        let w = self.rightmost_min_excess(x as i64 - 1, y as i64 - 1);
        self.rank1((w + 1) as usize) as u32
    }

    fn memory_bytes(&self) -> usize {
        self.words.len() * 8
            + self.rank.len() * 4
            + self.min_delta.len()
            + self.sb_min.len() * 4
            + self.sb_st.iter().map(|l| l.len() * 4).sum::<usize>()
            + self.select_sample.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::SparseTable;
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let h = Hrmq::new(&xs);
        assert_eq!(h.bp_len(), 14);
        assert_eq!(h.rmq(2, 6), 5);
        assert_eq!(h.rmq(0, 6), 5);
        assert_eq!(h.rmq(0, 3), 1);
        assert_eq!(h.rmq(4, 4), 4);
    }

    #[test]
    fn worked_bp_example() {
        // X = [2,1,3] -> BP "()(())" = bits 1,0,1,1,0,0
        let h = Hrmq::new(&[2.0, 1.0, 3.0]);
        assert_eq!(h.select_open(0), 0);
        assert_eq!(h.select_open(1), 2);
        assert_eq!(h.select_open(2), 3);
        assert_eq!(h.excess_at(0), 1);
        assert_eq!(h.excess_at(1), 0);
        assert_eq!(h.excess_at(5), 0);
        assert_eq!(h.rmq(0, 1), 1);
        assert_eq!(h.rmq(0, 2), 1);
        assert_eq!(h.rmq(1, 2), 1);
        assert_eq!(h.rmq(2, 2), 2);
        assert_eq!(h.rmq(0, 0), 0);
    }

    #[test]
    fn exhaustive_small_n() {
        let mut state = 99u64;
        for n in 1..=48usize {
            let xs: Vec<f32> = (0..n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 4) as f32)
                .collect();
            let h = Hrmq::new(&xs);
            for l in 0..n {
                for r in l..n {
                    assert_eq!(
                        h.rmq(l as u32, r as u32) as usize,
                        naive_rmq(&xs, l, r),
                        "n={n} l={l} r={r} xs={xs:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn property_random_vs_oracle() {
        check("hrmq vs sparse table", 120, |rng| {
            let xs = gen::f32_array(rng, 1..=4096);
            let h = Hrmq::new(&xs);
            let st = SparseTable::new(&xs);
            for _ in 0..48 {
                let (l, r) = gen::query(rng, xs.len());
                let got = h.rmq(l as u32, r as u32);
                let want = st.rmq(l as u32, r as u32);
                if got != want {
                    return Err(format!("n={} ({l},{r}): got {got} want {want}", xs.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_duplicates_and_adversarial() {
        check("hrmq ties/adversarial", 120, |rng| {
            let xs = if rng.below(2) == 0 {
                gen::dup_array(rng, 1..=2048, 2)
            } else {
                gen::adversarial_array(rng, 1..=2048)
            };
            let h = Hrmq::new(&xs);
            let st = SparseTable::new(&xs);
            for _ in 0..32 {
                let (l, r) = gen::query(rng, xs.len());
                let (got, want) = (h.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32));
                if got != want {
                    return Err(format!("({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn crosses_superblock_boundaries() {
        // Large enough that queries span multiple superblocks (2048 bits
        // per superblock => n > ~3000 gives several).
        let n = 20_000;
        let mut rng = crate::util::rng::Rng::new(5);
        let xs = rng.uniform_f32_vec(n);
        let h = Hrmq::new(&xs);
        let st = SparseTable::new(&xs);
        for _ in 0..500 {
            let l = rng.range(0, n - 1);
            let r = rng.range(l, n - 1);
            assert_eq!(h.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32), "({l},{r})");
        }
        // Full-range and long-range queries specifically.
        assert_eq!(h.rmq(0, (n - 1) as u32), st.rmq(0, (n - 1) as u32));
    }

    #[test]
    fn memory_is_near_succinct() {
        let n = 1 << 16;
        let xs = crate::util::rng::Rng::new(3).uniform_f32_vec(n);
        let h = Hrmq::new(&xs);
        let bits_per_elem = (h.memory_bytes() * 8) as f64 / n as f64;
        // 2 bits of parens + directories; should be far below one word
        // per element and in the ballpark the paper reports (~2.1n bits;
        // our coarser directories give a little more).
        assert!(bits_per_elem < 8.0, "bits/elem = {bits_per_elem}");
        assert!(bits_per_elem >= 2.0);
    }

    #[test]
    fn batch_parallel_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(8);
        let xs = rng.uniform_f32_vec(3000);
        let h = Hrmq::new(&xs);
        let queries: Vec<(u32, u32)> = (0..256)
            .map(|_| {
                let l = rng.range(0, 2999);
                let r = rng.range(l, 2999);
                (l as u32, r as u32)
            })
            .collect();
        assert_eq!(h.batch(&queries, 4), h.batch(&queries, 1));
    }
}

//! Cartesian tree construction — the shared substrate of both CPU/GPU
//! baselines (paper §2, §4): HRMQ encodes the tree as balanced
//! parentheses, and the LCA baseline answers `RMQ(l, r)` as
//! `LCA(node_l, node_r)` (the classical linear-time reduction).
//!
//! The tree of `X` has the (leftmost) minimum at the root; the left
//! subtree is the Cartesian tree of the prefix before it, the right
//! subtree that of the suffix after it. Built in O(n) with the rightmost-
//! spine stack. Ties: an equal element does **not** pop an earlier equal
//! (strictly-greater pops only), so the leftmost minimum is the ancestor
//! — preserving the leftmost-min convention end to end.

/// Sentinel for "no node".
pub const NIL: u32 = u32::MAX;

/// Array-backed Cartesian tree.
pub struct CartesianTree {
    pub parent: Vec<u32>,
    pub left: Vec<u32>,
    pub right: Vec<u32>,
    pub root: u32,
}

impl CartesianTree {
    /// O(n) stack build.
    pub fn build(xs: &[f32]) -> CartesianTree {
        let n = xs.len();
        assert!(n > 0, "empty array");
        let mut parent = vec![NIL; n];
        let mut left = vec![NIL; n];
        let mut right = vec![NIL; n];
        // Rightmost spine, bottom (root) at index 0.
        let mut stack: Vec<u32> = Vec::with_capacity(64);
        for i in 0..n as u32 {
            let mut last_popped = NIL;
            // Pop strictly greater values: equal elements stay, making the
            // earlier (leftmost) one the ancestor.
            while let Some(&top) = stack.last() {
                if xs[top as usize] > xs[i as usize] {
                    last_popped = top;
                    stack.pop();
                } else {
                    break;
                }
            }
            if last_popped != NIL {
                // The popped chain becomes i's left subtree.
                left[i as usize] = last_popped;
                parent[last_popped as usize] = i;
            }
            if let Some(&top) = stack.last() {
                right[top as usize] = i;
                parent[i as usize] = top;
            }
            stack.push(i);
        }
        let root = stack[0];
        CartesianTree { parent, left, right, root }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Depth of every node (root = 0), computed iteratively in index order
    /// is not possible (parents may be right of children), so an explicit
    /// DFS is used.
    pub fn depths(&self) -> Vec<u32> {
        let n = self.len();
        let mut depth = vec![0u32; n];
        let mut stack = vec![self.root];
        let mut visited = vec![false; n];
        visited[self.root as usize] = true;
        while let Some(v) = stack.pop() {
            for &c in &[self.left[v as usize], self.right[v as usize]] {
                if c != NIL {
                    depth[c as usize] = depth[v as usize] + 1;
                    visited[c as usize] = true;
                    stack.push(c);
                }
            }
        }
        debug_assert!(visited.iter().all(|&v| v));
        depth
    }

    /// Preorder numbering (1-based, as Schieber–Vishkin requires) and the
    /// preorder-sorted node list. Iterative DFS visiting left before
    /// right.
    pub fn preorder(&self) -> (Vec<u32>, Vec<u32>) {
        let n = self.len();
        let mut pre = vec![0u32; n]; // node -> preorder number (1-based)
        let mut order = Vec::with_capacity(n); // preorder position -> node
        let mut stack = vec![self.root];
        while let Some(v) = stack.pop() {
            order.push(v);
            pre[v as usize] = order.len() as u32;
            // Push right first so left is visited first.
            if self.right[v as usize] != NIL {
                stack.push(self.right[v as usize]);
            }
            if self.left[v as usize] != NIL {
                stack.push(self.left[v as usize]);
            }
        }
        (pre, order)
    }

    /// Subtree sizes, computed in reverse preorder (children before
    /// parents).
    pub fn subtree_sizes(&self, order: &[u32]) -> Vec<u32> {
        let mut size = vec![1u32; self.len()];
        for &v in order.iter().rev() {
            let p = self.parent[v as usize];
            if p != NIL {
                size[p as usize] += size[v as usize];
            }
        }
        size
    }

    /// Naive LCA by walking parents (test reference only; O(depth)).
    pub fn lca_naive(&self, mut u: u32, mut v: u32, depth: &[u32]) -> u32 {
        while depth[u as usize] > depth[v as usize] {
            u = self.parent[u as usize];
        }
        while depth[v as usize] > depth[u as usize] {
            v = self.parent[v as usize];
        }
        while u != v {
            u = self.parent[u as usize];
            v = self.parent[v as usize];
        }
        u
    }

    pub fn memory_bytes(&self) -> usize {
        3 * self.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example_root_is_min() {
        // X = [9,2,7,8,4,1,3] -> min at 5
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let t = CartesianTree::build(&xs);
        assert_eq!(t.root, 5);
        // In-order traversal must be 0..n (BST on positions).
        let mut inorder = Vec::new();
        fn walk(t: &CartesianTree, v: u32, out: &mut Vec<u32>) {
            if v == NIL {
                return;
            }
            walk(t, t.left[v as usize], out);
            out.push(v);
            walk(t, t.right[v as usize], out);
        }
        walk(&t, t.root, &mut inorder);
        assert_eq!(inorder, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn heap_property_and_tie_break() {
        let xs = [1.0, 1.0, 1.0];
        let t = CartesianTree::build(&xs);
        assert_eq!(t.root, 0, "leftmost equal element is the root");
        // parent value <= child value everywhere
        for v in 0..3 {
            let p = t.parent[v];
            if p != NIL {
                assert!(xs[p as usize] <= xs[v]);
            }
        }
    }

    #[test]
    fn lca_answers_rmq() {
        check("cartesian LCA == rmq", 100, |rng| {
            let xs = gen::dup_array(rng, 1..=256, 8);
            let t = CartesianTree::build(&xs);
            let depth = t.depths();
            for _ in 0..16 {
                let (l, r) = gen::query(rng, xs.len());
                let got = t.lca_naive(l as u32, r as u32, &depth) as usize;
                let want = naive_rmq(&xs, l, r);
                if got != want {
                    return Err(format!("({l},{r}): lca {got} vs rmq {want} xs={xs:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn preorder_and_sizes_are_consistent() {
        check("preorder intervals", 60, |rng| {
            let xs = gen::f32_array(rng, 1..=256);
            let t = CartesianTree::build(&xs);
            let (pre, order) = t.preorder();
            let size = t.subtree_sizes(&order);
            // The root is first, preorder numbers are a permutation of 1..=n.
            if order[0] != t.root {
                return Err("root not first in preorder".into());
            }
            let mut seen = vec![false; xs.len() + 1];
            for &p in &pre {
                if seen[p as usize] {
                    return Err("duplicate preorder number".into());
                }
                seen[p as usize] = true;
            }
            // Every child's preorder interval nests in its parent's.
            for v in 0..xs.len() {
                let p = t.parent[v];
                if p != NIL {
                    let (cv, cs) = (pre[v], size[v]);
                    let (pv, ps) = (pre[p as usize], size[p as usize]);
                    if !(pv < cv && cv + cs <= pv + ps) {
                        return Err(format!("interval not nested at node {v}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sorted_array_is_a_right_path() {
        let xs: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let t = CartesianTree::build(&xs);
        assert_eq!(t.root, 0);
        for i in 0..63u32 {
            assert_eq!(t.right[i as usize], i + 1);
            assert_eq!(t.left[i as usize], NIL);
        }
        let depth = t.depths();
        assert_eq!(depth[63], 63);
    }

    #[test]
    fn reverse_sorted_is_a_left_path() {
        let xs: Vec<f32> = (0..64).map(|i| (64 - i) as f32).collect();
        let t = CartesianTree::build(&xs);
        assert_eq!(t.root, 63);
        let depth = t.depths();
        assert_eq!(depth[0], 63);
    }
}

//! RTXRMQ — the paper's contribution (§5): RMQ solved as ray/triangle
//! closest-hit queries.
//!
//! Two modes, as in the paper:
//! - [`RtxMode::Flat`]: one normalized triangle space (§5.2, Algorithms
//!   1–3). Precision-limited to n ≤ 2^24.
//! - [`RtxMode::Blocks`]: the block-matrix extension (§5.3, Algorithms
//!   5–6): the array is split into BS-sized blocks laid out on a √nb grid
//!   of cells, with a second geometry for the block-minimums array; a
//!   query becomes 1–3 ray casts whose results are combined with a
//!   leftmost-preferring min.
//!
//! Two acceleration layouts behind [`RtxOptions::layout`] (see the "BVH
//! layouts" docs on [`crate::bvh`]): the default 4-wide SoA structure
//! specialized for +X rays, and the binary tree kept as the correctness
//! oracle. Batch execution hands per-worker [`Counters`] back from the
//! pool (no locks in the hot loop) and optionally processes each chunk
//! in left-endpoint order so consecutive rays of a Blocks-mode batch
//! walk the same cells (traversal coherence).
//!
//! Also implements the paper's future-work item (iii): **dynamic RMQ** —
//! point updates re-shape the affected triangles and *refit* both
//! acceleration layouts instead of rebuilding (`update_value`).

use super::{Query, RmqSolver};
use crate::bvh::traverse::{closest_hit_from, Counters, Hit, TraversalStack};
use crate::bvh::wide::{closest_hit_packet, closest_hit_wide_from, RayPacket, WideBvh, WideStack};
use crate::bvh::{AccelLayout, Builder};
use crate::geometry::blocks::BlockLayout;
use crate::geometry::precision::{best_block_size, config_valid, OptixLimits};
use crate::geometry::{flat, Ray};
use crate::rtcore::Scene;
use crate::util::pool;

/// Geometry organisation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RtxMode {
    /// Single normalized space (paper §5.2). Valid for n ≤ 2^24.
    Flat,
    /// Block-matrix of cells with a block-minimums geometry (§5.3).
    Blocks { block_size: usize },
}

/// Build-time options.
#[derive(Clone, Copy, Debug)]
pub struct RtxOptions {
    pub mode: RtxMode,
    pub builder: Builder,
    pub leaf_size: usize,
    /// Acceleration layout the query path traverses (default: wide SoA).
    pub layout: AccelLayout,
    /// Process each worker chunk in left-endpoint order (answers are
    /// written back to their original slots; per-query work is
    /// unchanged — this only improves cache/traversal coherence).
    pub sort_queries: bool,
    /// Traverse this many queries per shared BVH descent
    /// ([`crate::bvh::wide::closest_hit_packet`]); `0` keeps the scalar
    /// per-ray path. Only the wide layout packetizes (the binary layout
    /// is the correctness oracle and stays scalar). Answers are
    /// bit-identical at every width.
    pub packet_width: usize,
}

impl Default for RtxOptions {
    fn default() -> Self {
        RtxOptions {
            mode: RtxMode::Flat,
            builder: Builder::BinnedSah,
            leaf_size: 16,
            layout: AccelLayout::Wide,
            sort_queries: true,
            packet_width: 0,
        }
    }
}

/// Per-worker traversal state for either layout (allocation-free hot
/// loop — one per worker, reused across queries).
#[derive(Default)]
pub struct RtxScratch {
    pub bin: TraversalStack,
    pub wide: WideStack,
    /// Reused ray bundle for the packetized drivers.
    pub packet: RayPacket,
}

impl RtxScratch {
    pub fn new() -> RtxScratch {
        RtxScratch::default()
    }
}

/// Shared chunked batch driver for scratch-carrying solvers (RTXRMQ and
/// the sharded engine): workers process disjoint chunks with
/// thread-local [`RtxScratch`] and [`Counters`]; the per-chunk counters
/// come back through the pool and are summed here — no mutex or atomic
/// in the loop. When `sort_queries` is set, each chunk is walked in
/// left-endpoint order (answers land in their original slots; per-query
/// work is unchanged — this only improves cache/traversal coherence).
pub(crate) fn batch_counted_impl(
    queries: &[Query],
    workers: usize,
    sort_queries: bool,
    rmq: impl Fn(u32, u32, &mut RtxScratch, &mut Counters) -> u32 + Sync,
) -> (Vec<u32>, Counters) {
    let mut out = vec![0u32; queries.len()];
    let per_worker: Vec<Counters> = pool::map_chunks_mut(&mut out, workers, |off, slice| {
        let mut scratch = RtxScratch::new();
        let mut c = Counters::default();
        if sort_queries && slice.len() > 1 {
            let mut order: Vec<u32> = (0..slice.len() as u32).collect();
            order.sort_unstable_by_key(|&k| queries[off + k as usize].0);
            for &k in &order {
                let (l, r) = queries[off + k as usize];
                slice[k as usize] = rmq(l, r, &mut scratch, &mut c);
            }
        } else {
            for (k, o) in slice.iter_mut().enumerate() {
                let (l, r) = queries[off + k];
                *o = rmq(l, r, &mut scratch, &mut c);
            }
        }
        c
    });
    let mut total = Counters::default();
    for c in &per_worker {
        total.add(c);
    }
    (out, total)
}

/// The RTXRMQ solver.
pub struct RtxRmq {
    xs: Vec<f32>,
    theta: f32,
    scene: Scene,
    opts: RtxOptions,
    /// Blocks mode only.
    layout: Option<BlockLayout>,
    /// Blocks mode: global argmin index per block.
    block_argmin: Vec<u32>,
    /// Topology links for path refits, built lazily on the first
    /// [`update_values_point`](Self::update_values_point) call (refits
    /// never change topology, so they stay valid forever).
    refit_links: Option<crate::rtcore::SceneRefitLinks>,
}

impl RtxRmq {
    /// Build with explicit options.
    pub fn with_options(xs: &[f32], opts: RtxOptions) -> RtxRmq {
        let n = xs.len();
        assert!(n > 0, "empty array");
        let theta = flat::ray_origin_x(xs);
        match opts.mode {
            RtxMode::Flat => {
                assert!(n <= 1 << 24, "flat mode is precision-limited to n <= 2^24 (paper §5.2)");
                let tris = flat::build_scene(xs);
                let scene = Scene::with_layout(tris, opts.builder, opts.leaf_size, opts.layout);
                RtxRmq {
                    xs: xs.to_vec(),
                    theta,
                    scene,
                    opts,
                    layout: None,
                    block_argmin: vec![],
                    refit_links: None,
                }
            }
            RtxMode::Blocks { block_size } => {
                let limits = OptixLimits::default();
                if let Err(e) = config_valid(n, block_size, &limits) {
                    panic!("invalid block config n={n} bs={block_size}: {e:?} (paper Eq. 2 / OptiX limits)");
                }
                let layout = BlockLayout::new(n, block_size);
                let (tris, _mins, argmins) = layout.build_scene(xs);
                let scene = Scene::with_layout(tris, opts.builder, opts.leaf_size, opts.layout);
                RtxRmq {
                    xs: xs.to_vec(),
                    theta,
                    scene,
                    opts,
                    layout: Some(layout),
                    block_argmin: argmins,
                    refit_links: None,
                }
            }
        }
    }

    /// Build with the auto-tuned block size (√n-balanced, Eq.2-valid),
    /// falling back to flat for small inputs — the configuration the
    /// paper's 2D heat map projects to (§6.3).
    pub fn new_auto(xs: &[f32]) -> RtxRmq {
        let n = xs.len();
        let limits = OptixLimits::default();
        // Flat is competitive only while the whole array fits one
        // normalized space comfortably; the paper switches to blocks for
        // large n. We use blocks whenever a valid config exists and
        // n > 2^16 (small scenes gain nothing from the block stage).
        if n > (1 << 16) {
            if let Some(bs) = best_block_size(n, &limits) {
                return Self::with_options(
                    xs,
                    RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
                );
            }
        }
        Self::with_options(xs, RtxOptions::default())
    }

    /// [`new_auto`](Self::new_auto) with the batch-driver knobs
    /// overridden (the coordinator's `--packet-width` /
    /// `--no-sort-queries` surface). Geometry and mode tuning are
    /// unchanged — only the traversal driver differs, and answers are
    /// bit-identical for every setting.
    pub fn new_auto_tuned(xs: &[f32], packet_width: usize, sort_queries: bool) -> RtxRmq {
        let mut r = Self::new_auto(xs);
        r.opts.packet_width = packet_width;
        r.opts.sort_queries = sort_queries;
        r
    }

    pub fn mode(&self) -> RtxMode {
        self.opts.mode
    }

    /// Acceleration layout in use.
    pub fn accel_layout(&self) -> AccelLayout {
        self.scene.layout()
    }

    pub fn scene(&self) -> &Scene {
        &self.scene
    }

    /// Primitive count of the built geometry.
    pub fn prim_count(&self) -> usize {
        self.scene.tris.len()
    }

    /// One ray cast through whichever layout is built.
    #[inline]
    fn cast(
        &self,
        ray: &Ray,
        scratch: &mut RtxScratch,
        c: &mut Counters,
        init: Option<Hit>,
    ) -> Option<Hit> {
        match &self.scene.wide {
            Some(wb) => closest_hit_wide_from(wb, ray, &mut scratch.wide, c, init),
            None => {
                closest_hit_from(&self.scene.bvh, &self.scene.tris, ray, &mut scratch.bin, c, init)
            }
        }
    }

    /// One query with explicit traversal state and counters (hot path;
    /// the trait's `rmq` wraps this).
    pub fn rmq_counted(&self, l: u32, r: u32, scratch: &mut RtxScratch, c: &mut Counters) -> u32 {
        match self.layout {
            None => self.rmq_flat(l, r, scratch, c),
            Some(layout) => self.rmq_blocks(&layout, l, r, scratch, c),
        }
    }

    fn rmq_flat(&self, l: u32, r: u32, scratch: &mut RtxScratch, c: &mut Counters) -> u32 {
        let ray = flat::ray_for_query(l, r, self.xs.len(), self.theta);
        let hit = self.cast(&ray, scratch, c, None).expect("in-range query must hit");
        hit.prim
    }

    /// Algorithm 6.
    fn rmq_blocks(
        &self,
        layout: &BlockLayout,
        l: u32,
        r: u32,
        scratch: &mut RtxScratch,
        c: &mut Counters,
    ) -> u32 {
        let (l, r) = (l as usize, r as usize);
        let bs = layout.bs;
        let (bl, br) = (l / bs, r / bs);
        // Case #1: query within one block — a single ray.
        if bl == br {
            let ray = layout.ray_for_block_query(bl, l % bs, r % bs, self.theta);
            let hit = self.cast(&ray, scratch, c, None).expect("block sub-query must hit");
            return self.to_global_index(layout, hit);
        }
        // Case #2: left partial, right partial, plus covered blocks —
        // with the paper's payload-min optimisation: the running best
        // hit is carried into the later rays so they prune against it.
        // Sub-rays run left to right, and the carried hit only loses on
        // strictly smaller t (equal-t keeps the earlier prim), preserving
        // the leftmost-min convention: candidate index order is left
        // block < interior < right block.
        let left_ray = layout.ray_for_block_query(bl, l % bs, layout.block_len(bl) - 1, self.theta);
        let mut best = self.cast(&left_ray, scratch, c, None);
        if br - bl > 1 {
            let mid_ray = layout.ray_for_blockmin_query(bl + 1, br - 1, self.theta);
            best = self.cast(&mid_ray, scratch, c, best);
        }
        let right_ray = layout.ray_for_block_query(br, 0, r % bs, self.theta);
        best = self.cast(&right_ray, scratch, c, best);
        self.to_global_index(layout, best.expect("left partial block always hits"))
    }

    /// Batch execution with counters (the bench-harness entry point);
    /// see [`batch_counted_impl`] for the worker/scratch/sort structure.
    /// With `packet_width > 0` and the wide layout built, worker chunks
    /// run through the packetized driver instead — same answers, shared
    /// node fetches (see the "Packet traversal" note on [`crate::bvh`]).
    pub fn batch_counted(&self, queries: &[Query], workers: usize) -> (Vec<u32>, Counters) {
        if self.opts.packet_width > 0 {
            if let Some(wb) = &self.scene.wide {
                return self.batch_counted_packet(wb, queries, workers);
            }
        }
        batch_counted_impl(queries, workers, self.opts.sort_queries, |l, r, scratch, c| {
            self.rmq_counted(l, r, scratch, c)
        })
    }

    /// Packetized batch driver: each worker chunk is (optionally) put in
    /// left-endpoint order — the same sort the scalar path uses — then
    /// consecutive runs of `packet_width` queries descend the wide BVH
    /// together. Flat mode is a single phase; Blocks mode runs the
    /// Algorithm-6 decomposition in three packet phases so every
    /// sub-ray keeps its exact scalar seed:
    ///
    /// 1. first rays (single-block queries and left partials), unseeded;
    /// 2. summary rays for queries spanning > 2 blocks, each seeded with
    ///    its own phase-1 hit (packets carry per-ray seeds);
    /// 3. right partial rays, seeded with the running best.
    ///
    /// Per-ray results are bit-identical to the scalar casts, so the
    /// combined Algorithm-6 answer is too.
    fn batch_counted_packet(
        &self,
        wb: &WideBvh,
        queries: &[Query],
        workers: usize,
    ) -> (Vec<u32>, Counters) {
        let width = self.opts.packet_width.max(1);
        let sort = self.opts.sort_queries;
        let mut out = vec![0u32; queries.len()];
        let per_worker: Vec<Counters> = pool::map_chunks_mut(&mut out, workers, |off, slice| {
            let mut ws = WideStack::new();
            let mut packet = RayPacket::new();
            let mut c = Counters::default();
            let mut order: Vec<u32> = (0..slice.len() as u32).collect();
            if sort && slice.len() > 1 {
                order.sort_unstable_by_key(|&k| queries[off + k as usize].0);
            }
            let mut group_out: Vec<u32> = Vec::with_capacity(width);
            for group in order.chunks(width) {
                group_out.clear();
                group_out.resize(group.len(), 0);
                match &self.layout {
                    None => {
                        packet.clear();
                        for &k in group {
                            let (l, r) = queries[off + k as usize];
                            let ray = flat::ray_for_query(l, r, self.xs.len(), self.theta);
                            packet.push(&ray, None);
                        }
                        closest_hit_packet(wb, &mut packet, &mut ws, &mut c);
                        for (i, &k) in group.iter().enumerate() {
                            slice[k as usize] =
                                packet.hit(i).expect("in-range query must hit").prim;
                        }
                    }
                    Some(layout) => {
                        let qs: Vec<Query> =
                            group.iter().map(|&k| queries[off + k as usize]).collect();
                        self.rmq_blocks_packet(
                            layout,
                            wb,
                            &qs,
                            &mut group_out,
                            &mut packet,
                            &mut ws,
                            &mut c,
                        );
                        for (i, &k) in group.iter().enumerate() {
                            slice[k as usize] = group_out[i];
                        }
                    }
                }
            }
            c
        });
        let mut total = Counters::default();
        for c in &per_worker {
            total.add(c);
        }
        (out, total)
    }

    /// Algorithm 6 over a packet of queries (see
    /// [`batch_counted_packet`](Self::batch_counted_packet) for the
    /// three-phase structure).
    fn rmq_blocks_packet(
        &self,
        layout: &BlockLayout,
        wb: &WideBvh,
        queries: &[Query],
        out: &mut [u32],
        packet: &mut RayPacket,
        ws: &mut WideStack,
        c: &mut Counters,
    ) {
        let bs = layout.bs;
        let g = queries.len();
        // Phase 1: one first ray per query.
        packet.clear();
        let mut blocks: Vec<(usize, usize)> = Vec::with_capacity(g);
        for &(l, r) in queries {
            let (l, r) = (l as usize, r as usize);
            let (bl, br) = (l / bs, r / bs);
            let ray = if bl == br {
                layout.ray_for_block_query(bl, l % bs, r % bs, self.theta)
            } else {
                layout.ray_for_block_query(bl, l % bs, layout.block_len(bl) - 1, self.theta)
            };
            packet.push(&ray, None);
            blocks.push((bl, br));
        }
        closest_hit_packet(wb, packet, ws, c);
        let mut best: Vec<Option<Hit>> = (0..g).map(|i| packet.hit(i)).collect();
        // Phase 2: summary rays for queries spanning covered blocks,
        // seeded with each query's own running best.
        packet.clear();
        let mut members: Vec<usize> = Vec::with_capacity(g);
        for (i, &(bl, br)) in blocks.iter().enumerate() {
            if br - bl > 1 {
                let ray = layout.ray_for_blockmin_query(bl + 1, br - 1, self.theta);
                packet.push(&ray, best[i]);
                members.push(i);
            }
        }
        if !packet.is_empty() {
            closest_hit_packet(wb, packet, ws, c);
            for (j, &i) in members.iter().enumerate() {
                best[i] = packet.hit(j);
            }
        }
        // Phase 3: right partial rays for multi-block queries.
        packet.clear();
        members.clear();
        for (i, &(bl, br)) in blocks.iter().enumerate() {
            if bl != br {
                let r = queries[i].1 as usize;
                let ray = layout.ray_for_block_query(br, 0, r % bs, self.theta);
                packet.push(&ray, best[i]);
                members.push(i);
            }
        }
        if !packet.is_empty() {
            closest_hit_packet(wb, packet, ws, c);
            for (j, &i) in members.iter().enumerate() {
                best[i] = packet.hit(j);
            }
        }
        for i in 0..g {
            let hit = best[i].expect("left partial block always hits");
            out[i] = self.to_global_index(layout, hit);
        }
    }

    /// Resolve a group of queries in one shared packet descent (flat
    /// mode only — the sharded engine's per-block solvers). Answers are
    /// bit-identical to per-query [`rmq_counted`](Self::rmq_counted);
    /// the binary layout falls back to scalar casts.
    pub fn rmq_group_packet(
        &self,
        queries: &[Query],
        out: &mut [u32],
        scratch: &mut RtxScratch,
        c: &mut Counters,
    ) {
        debug_assert!(self.layout.is_none(), "packet group entry is flat-mode only");
        debug_assert_eq!(queries.len(), out.len());
        match &self.scene.wide {
            Some(wb) => {
                scratch.packet.clear();
                for &(l, r) in queries {
                    let ray = flat::ray_for_query(l, r, self.xs.len(), self.theta);
                    scratch.packet.push(&ray, None);
                }
                closest_hit_packet(wb, &mut scratch.packet, &mut scratch.wide, c);
                for (i, o) in out.iter_mut().enumerate() {
                    *o = scratch.packet.hit(i).expect("in-range query must hit").prim;
                }
            }
            None => {
                for (i, &(l, r)) in queries.iter().enumerate() {
                    out[i] = self.rmq_counted(l, r, scratch, c);
                }
            }
        }
    }

    /// Map a Blocks-mode hit back to a global element index (block-min
    /// primitives resolve through the per-block argmin table).
    #[inline]
    fn to_global_index(&self, layout: &BlockLayout, hit: Hit) -> u32 {
        let prim = hit.prim as usize;
        if prim >= layout.n {
            self.block_argmin[prim - layout.n]
        } else {
            prim as u32
        }
    }

    /// Dynamic RMQ (paper §7.iii): update one value, re-shape the
    /// affected triangles, and refit the BVH in place (no rebuild).
    pub fn update_value(&mut self, i: usize, x: f32) {
        self.update_values(&[(i, x)]);
    }

    /// Batched dynamic update: apply every point update, re-shape only
    /// the touched triangles, then refit **once** — the paper's
    /// "update/rebuild functions used in a balanced way" (§7.iii). Both
    /// acceleration layouts are refit.
    pub fn update_values(&mut self, updates: &[(usize, f32)]) {
        for &(i, x) in updates {
            self.apply_update(i, x);
        }
        self.scene.refit();
    }

    /// Batched dynamic update via **path refit**: re-shape the touched
    /// triangles, then recompute only their leaf-to-root bound paths in
    /// both acceleration layouts — Θ(k·log n) against the full sweep's
    /// Θ(n). This is the fast path the sharded engine's summary solver
    /// takes when a batch moves a single block minimum. Falls back to
    /// the full refit when the batch touches enough of the scene that
    /// per-path walks would cost more (same result either way).
    pub fn update_values_point(&mut self, updates: &[(usize, f32)]) {
        let mut touched: Vec<u32> = Vec::with_capacity(updates.len() * 2);
        for &(i, x) in updates {
            self.apply_update(i, x);
            touched.push(i as u32);
            if let Some(layout) = self.layout {
                // Blocks mode re-shapes the owning block-min triangle too.
                touched.push((layout.n + i / layout.bs) as u32);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        if touched.len() * 16 > self.scene.tris.len() {
            self.scene.refit();
            return;
        }
        if self.refit_links.is_none() {
            self.refit_links = Some(self.scene.refit_links());
        }
        let links = self.refit_links.as_ref().expect("just built");
        self.scene.refit_prims(&touched, links);
    }

    fn apply_update(&mut self, i: usize, x: f32) {
        assert!(i < self.xs.len());
        self.xs[i] = x;
        // theta must stay strictly below all values.
        self.theta = self.theta.min(x - 1.0);
        match self.layout {
            None => {
                let n = self.xs.len();
                self.scene.tris[i] = flat::triangle_for(x, i, n);
            }
            Some(layout) => {
                self.scene.tris[i] = layout.triangle_for_element(x, i);
                // Recompute the block's min and its block-min triangle.
                let b = i / layout.bs;
                let start = b * layout.bs;
                let end = start + layout.block_len(b);
                let mut arg = start;
                for k in start + 1..end {
                    if self.xs[k] < self.xs[arg] {
                        arg = k;
                    }
                }
                self.block_argmin[b] = arg as u32;
                let mut t = layout.triangle_for_blockmin(self.xs[arg], b);
                t.prim = (layout.n + b) as u32;
                self.scene.tris[layout.n + b] = t;
            }
        }
    }

    /// Values slice (the solver answers by value as well as index —
    /// paper §6.7's point about RTXRMQ answering both).
    pub fn value_of(&self, idx: u32) -> f32 {
        self.xs[idx as usize]
    }
}

impl RmqSolver for RtxRmq {
    fn name(&self) -> &'static str {
        "RTXRMQ"
    }

    fn rmq(&self, l: u32, r: u32) -> u32 {
        let mut scratch = RtxScratch::new();
        let mut c = Counters::default();
        self.rmq_counted(l, r, &mut scratch, &mut c)
    }

    fn batch(&self, queries: &[Query], workers: usize) -> Vec<u32> {
        self.batch_counted(queries, workers).0
    }

    fn memory_bytes(&self) -> usize {
        // Every owned allocation: acceleration structures + triangles,
        // block tables, the solver's value copy (`xs` is load-bearing —
        // answers-by-value and update rescans read it), and the lazily
        // built refit links once the update path has materialized them.
        // (Table 2's paper convention excluded the input copy; resident
        // accounting here is deliberately truthful instead — the paper
        // comparison lives in `Bvh::optix_size_estimate`.)
        self.scene.memory_bytes()
            + self.block_argmin.len() * 4
            + self.xs.len() * 4
            + self.refit_links.as_ref().map_or(0, |l| l.memory_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::naive_rmq;
    use crate::rmq::sparse_table::SparseTable;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example_flat() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let s = RtxRmq::with_options(&xs, RtxOptions::default());
        assert_eq!(s.accel_layout(), AccelLayout::Wide);
        assert_eq!(s.rmq(2, 6), 5);
        assert_eq!(s.rmq(0, 6), 5);
        assert_eq!(s.rmq(3, 3), 3);
    }

    #[test]
    fn paper_example_blocks() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        for layout in AccelLayout::all() {
            let s = RtxRmq::with_options(
                &xs,
                RtxOptions {
                    mode: RtxMode::Blocks { block_size: 3 },
                    layout,
                    ..Default::default()
                },
            );
            for l in 0..7u32 {
                for r in l..7u32 {
                    assert_eq!(
                        s.rmq(l, r) as usize,
                        naive_rmq(&xs, l as usize, r as usize),
                        "{layout:?} ({l},{r})"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_matches_oracle() {
        check("rtx flat vs oracle", 80, |rng| {
            let xs = gen::f32_array(rng, 1..=1024);
            let s = RtxRmq::with_options(&xs, RtxOptions::default());
            let st = SparseTable::new(&xs);
            for _ in 0..24 {
                let (l, r) = gen::query(rng, xs.len());
                let (got, want) = (s.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32));
                if got != want {
                    return Err(format!("({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocks_match_oracle_various_bs() {
        check("rtx blocks vs oracle", 60, |rng| {
            let xs = gen::f32_array(rng, 2..=2048);
            let n = xs.len();
            let bs = 1usize << rng.range(0, 7);
            let s = RtxRmq::with_options(
                &xs,
                RtxOptions { mode: RtxMode::Blocks { block_size: bs }, ..Default::default() },
            );
            let st = SparseTable::new(&xs);
            for _ in 0..24 {
                let (l, r) = gen::query(rng, n);
                let (got, want) = (s.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32));
                if got != want {
                    return Err(format!("n={n} bs={bs} ({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn layouts_agree_across_modes_and_builders() {
        // Wide vs binary vs the oracle, over both geometry modes and
        // both builders, batched (exercises the sorted chunk path too).
        check("accel layouts agree", 30, |rng| {
            let xs = gen::f32_array(rng, 2..=1024);
            let n = xs.len();
            let bs = 1usize << rng.range(1, 6);
            let st = SparseTable::new(&xs);
            let queries: Vec<Query> = (0..48)
                .map(|_| {
                    let (l, r) = gen::query(rng, n);
                    (l as u32, r as u32)
                })
                .collect();
            let want = st.batch(&queries, 1);
            for builder in [Builder::BinnedSah, Builder::Lbvh] {
                for mode in [RtxMode::Flat, RtxMode::Blocks { block_size: bs }] {
                    for layout in AccelLayout::all() {
                        let s = RtxRmq::with_options(
                            &xs,
                            RtxOptions { mode, builder, layout, ..Default::default() },
                        );
                        let (got, c) = s.batch_counted(&queries, 2);
                        if got != want {
                            return Err(format!(
                                "{builder:?}/{mode:?}/{layout:?}: batch mismatch"
                            ));
                        }
                        if c.rays == 0 {
                            return Err("no rays counted".into());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocks_ties_leftmost_across_subqueries() {
        check("rtx blocks leftmost ties", 60, |rng| {
            let xs = gen::dup_array(rng, 4..=512, 2);
            let bs = 1usize << rng.range(1, 5);
            for layout in AccelLayout::all() {
                let s = RtxRmq::with_options(
                    &xs,
                    RtxOptions {
                        mode: RtxMode::Blocks { block_size: bs },
                        layout,
                        ..Default::default()
                    },
                );
                for _ in 0..12 {
                    let (l, r) = gen::query(rng, xs.len());
                    let want = naive_rmq(&xs, l, r);
                    let got = s.rmq(l as u32, r as u32) as usize;
                    if got != want {
                        return Err(format!(
                            "{layout:?} bs={bs} ({l},{r}): got {got} want {want}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn auto_mode_picks_blocks_for_large_flat_for_small() {
        let mut rng = crate::util::rng::Rng::new(50);
        let small = rng.uniform_f32_vec(1 << 10);
        assert_eq!(RtxRmq::new_auto(&small).mode(), RtxMode::Flat);
        let large = rng.uniform_f32_vec((1 << 16) + 1);
        let auto = RtxRmq::new_auto(&large);
        // The wide layout is the default for the auto-tuned solver.
        assert_eq!(auto.accel_layout(), AccelLayout::Wide);
        match auto.mode() {
            RtxMode::Blocks { block_size } => assert!(block_size.is_power_of_two()),
            m => panic!("expected blocks, got {m:?}"),
        }
    }

    #[test]
    fn batch_and_counters() {
        let mut rng = crate::util::rng::Rng::new(51);
        let xs = rng.uniform_f32_vec(600);
        let s = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: 32 }, ..Default::default() },
        );
        let st = SparseTable::new(&xs);
        let queries: Vec<(u32, u32)> = (0..128)
            .map(|_| {
                let l = rng.range(0, 599) as u32;
                (l, rng.range(l as usize, 599) as u32)
            })
            .collect();
        let (got, counters) = s.batch_counted(&queries, 3);
        assert_eq!(got, st.batch(&queries, 1));
        // 1-3 rays per query.
        assert!(counters.rays >= 128 && counters.rays <= 3 * 128, "rays = {}", counters.rays);
        assert!(counters.nodes_visited > 0);
    }

    #[test]
    fn sorted_chunks_change_nothing() {
        let mut rng = crate::util::rng::Rng::new(53);
        let xs = rng.uniform_f32_vec(900);
        let queries: Vec<(u32, u32)> = (0..200)
            .map(|_| {
                let l = rng.range(0, 899) as u32;
                (l, rng.range(l as usize, 899) as u32)
            })
            .collect();
        let sorted = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: 32 }, ..Default::default() },
        );
        let unsorted = RtxRmq::with_options(
            &xs,
            RtxOptions {
                mode: RtxMode::Blocks { block_size: 32 },
                sort_queries: false,
                ..Default::default()
            },
        );
        let (a, ca) = sorted.batch_counted(&queries, 3);
        let (b, cb) = unsorted.batch_counted(&queries, 3);
        assert_eq!(a, b);
        // Per-query work is order-independent.
        assert_eq!(ca, cb);
    }

    #[test]
    fn packet_batches_match_scalar_both_modes() {
        // The public A/B surface: packet_width ∈ {1, 4, 7, 8, 16} must
        // return the exact scalar batch in both geometry modes, with and
        // without chunk sorting (tie-heavy arrays pin leftmost ties).
        check("rtx packet batch == scalar batch", 20, |rng| {
            let xs = gen::dup_array(rng, 8..=900, 2);
            let n = xs.len();
            let bs = 1usize << rng.range(1, 5);
            let queries: Vec<Query> = (0..96)
                .map(|_| {
                    let (l, r) = gen::query(rng, n);
                    (l as u32, r as u32)
                })
                .collect();
            for mode in [RtxMode::Flat, RtxMode::Blocks { block_size: bs }] {
                for sort_queries in [true, false] {
                    let scalar = RtxRmq::with_options(
                        &xs,
                        RtxOptions { mode, sort_queries, ..Default::default() },
                    );
                    let want = scalar.batch_counted(&queries, 2).0;
                    for packet_width in [1usize, 4, 7, 8, 16] {
                        let packed = RtxRmq::with_options(
                            &xs,
                            RtxOptions { mode, sort_queries, packet_width, ..Default::default() },
                        );
                        let (got, c) = packed.batch_counted(&queries, 2);
                        if got != want {
                            return Err(format!(
                                "{mode:?} sort={sort_queries} width={packet_width}: mismatch"
                            ));
                        }
                        if c.rays == 0 || c.node_fetches == 0 {
                            return Err("packet path counted no work".into());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packet_width_ignored_on_binary_layout() {
        // The binary layout is the correctness oracle: packet_width must
        // silently fall back to the scalar driver there.
        let mut rng = crate::util::rng::Rng::new(57);
        let xs = rng.uniform_f32_vec(400);
        let s = RtxRmq::with_options(
            &xs,
            RtxOptions { layout: AccelLayout::Binary, packet_width: 8, ..Default::default() },
        );
        let queries: Vec<Query> = (0..64)
            .map(|_| {
                let l = rng.range(0, 399) as u32;
                (l, rng.range(l as usize, 399) as u32)
            })
            .collect();
        let (got, c) = s.batch_counted(&queries, 2);
        let st = SparseTable::new(&xs);
        assert_eq!(got, st.batch(&queries, 1));
        // Scalar counting: one fetch per node pop.
        assert_eq!(c.node_fetches, c.nodes_visited);
    }

    #[test]
    fn packet_batches_amortize_node_fetches() {
        // Sorted small-range batches: node fetches per query must
        // strictly decrease as the packet widens (the ISSUE's acceptance
        // criterion, asserted here at the solver level).
        let mut rng = crate::util::rng::Rng::new(58);
        let xs = rng.uniform_f32_vec(1 << 14);
        let queries: Vec<Query> = (0..512u32)
            .map(|i| {
                let l = i * 8;
                (l, l + 100)
            })
            .collect();
        let mut fetches = Vec::new();
        let mut answers: Option<Vec<u32>> = None;
        for packet_width in [0usize, 4, 8, 16] {
            let s = RtxRmq::with_options(
                &xs,
                RtxOptions {
                    mode: RtxMode::Blocks { block_size: 128 },
                    packet_width,
                    ..Default::default()
                },
            );
            let (got, c) = s.batch_counted(&queries, 1);
            match &answers {
                None => answers = Some(got),
                Some(w) => assert_eq!(w, &got, "width {packet_width} changed answers"),
            }
            fetches.push(c.node_fetches);
        }
        for w in 1..fetches.len() {
            assert!(
                fetches[w] < fetches[w - 1],
                "node fetches not strictly decreasing across widths: {fetches:?}"
            );
        }
    }

    #[test]
    fn dynamic_update_refit() {
        // Paper future-work iii: point updates + refit keep answers exact
        // on both layouts.
        check("dynamic updates", 30, |rng| {
            let mut xs = gen::f32_array(rng, 8..=256);
            let n = xs.len();
            let bs = 1usize << rng.range(1, 4);
            for layout in AccelLayout::all() {
                let mut s = RtxRmq::with_options(
                    &xs,
                    RtxOptions {
                        mode: RtxMode::Blocks { block_size: bs },
                        layout,
                        ..Default::default()
                    },
                );
                for _ in 0..8 {
                    let i = rng.range(0, n - 1);
                    let v = rng.f32();
                    xs[i] = v;
                    s.update_value(i, v);
                    let (l, r) = gen::query(rng, n);
                    let want = naive_rmq(&xs, l, r);
                    let got = s.rmq(l as u32, r as u32) as usize;
                    if got != want {
                        return Err(format!(
                            "{layout:?} after update[{i}]={v}: ({l},{r}) got {got} want {want}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn point_update_refit_matches_full_refit() {
        // `update_values_point` (path refit) and `update_values` (full
        // bottom-up sweep) must stay answer-identical on both geometry
        // modes — the refit-vs-rebuild pin for the sharded summary's
        // single-min fast path.
        check("point vs full update refit", 25, |rng| {
            let mut xs = gen::f32_array(rng, 8..=400);
            let n = xs.len();
            for mode in [RtxMode::Flat, RtxMode::Blocks { block_size: 8 }] {
                let opts = RtxOptions { mode, ..Default::default() };
                let mut point = RtxRmq::with_options(&xs, opts);
                let mut full = RtxRmq::with_options(&xs, opts);
                for _ in 0..6 {
                    let batch: Vec<(usize, f32)> =
                        (0..2).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
                    for &(i, v) in &batch {
                        xs[i] = v;
                    }
                    point.update_values_point(&batch);
                    full.update_values(&batch);
                    for _ in 0..10 {
                        let (l, r) = gen::query(rng, n);
                        let want = naive_rmq(&xs, l, r);
                        let (a, b) =
                            (point.rmq(l as u32, r as u32), full.rmq(l as u32, r as u32));
                        if a as usize != want || b as usize != want {
                            return Err(format!(
                                "{mode:?} ({l},{r}): point {a} full {b} want {want}"
                            ));
                        }
                    }
                }
                let scene = point.scene();
                scene.bvh.validate(&scene.tris)?;
                if let Some(w) = &scene.wide {
                    w.validate(&scene.tris)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dynamic_update_flat_mode() {
        let mut xs = vec![0.5f32, 0.4, 0.3, 0.2, 0.9, 0.8];
        let mut s = RtxRmq::with_options(&xs, RtxOptions::default());
        assert_eq!(s.rmq(0, 5), 3);
        xs[4] = 0.01;
        s.update_value(4, 0.01);
        assert_eq!(s.rmq(0, 5), 4);
        assert_eq!(s.value_of(4), 0.01);
    }

    #[test]
    #[should_panic(expected = "invalid block config")]
    fn rejects_invalid_block_config() {
        // Way past Eq. 2: huge block size with many blocks.
        let xs = vec![0.0f32; 1 << 20];
        let _ = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: 1 << 19 }, ..Default::default() },
        );
    }

    #[test]
    fn memory_reported() {
        let xs = crate::util::rng::Rng::new(52).uniform_f32_vec(1 << 10);
        let s = RtxRmq::new_auto(&xs);
        // BVH + triangles dominate; must exceed raw input size (Table 2's
        // point about RTXRMQ's memory cost).
        assert!(s.memory_bytes() > (1 << 10) * 4);
    }

    #[test]
    fn memory_counts_every_owned_allocation() {
        // The reported sum must equal the component-wise tally: scene +
        // block tables + the value copy — and grow by exactly the link
        // tables once a point update materializes them lazily.
        let xs = crate::util::rng::Rng::new(54).uniform_f32_vec(512);
        let mut s = RtxRmq::with_options(
            &xs,
            RtxOptions { mode: RtxMode::Blocks { block_size: 16 }, ..Default::default() },
        );
        let before = s.memory_bytes();
        assert_eq!(
            before,
            s.scene.memory_bytes() + s.block_argmin.len() * 4 + s.xs.len() * 4
        );
        s.update_values_point(&[(7, 0.25)]);
        let links = s.refit_links.as_ref().expect("point update builds links");
        assert_eq!(s.memory_bytes(), before + links.memory_bytes());
        assert!(links.memory_bytes() > 0);
    }
}

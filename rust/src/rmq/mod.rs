//! Range-minimum-query solvers.
//!
//! The paper's problem statement (§2): given `X = [x_0 .. x_{n-1}]` and
//! `l ≤ r < n`, `RMQ(l, r) = argmin_{l ≤ k ≤ r} x_k`, preferring the
//! **leftmost** position on ties. Every solver in this module implements
//! [`RmqSolver`] and is property-tested against the sparse-table oracle.
//!
//! Solvers (paper §6.1):
//! - [`sparse_table::SparseTable`] — ⟨O(n log n), O(1)⟩ oracle (ground truth).
//! - [`exhaustive::Exhaustive`] — the paper's EXHAUSTIVE baseline.
//! - [`hrmq::Hrmq`] — succinct balanced-parentheses RMQ in the style of
//!   Ferrada & Navarro (the paper's CPU state of the art, query-parallel).
//! - [`lca::LcaRmq`] — Schieber–Vishkin inline LCA over the Cartesian tree
//!   (the paper's GPU state of the art, Polak et al., batch-parallel).
//! - [`rtx::RtxRmq`] — the paper's contribution: RMQ as ray/triangle
//!   closest-hit queries over a BVH (RT-core simulator substrate).
//! - [`sharded::ShardedRmq`] — two-level blocked decomposition over
//!   pluggable per-block solvers (see below).
//!
//! # Sharding & point updates (design note)
//!
//! The paper's central result (Fig. 10) is that RTXRMQ wins when query
//! ranges are *small relative to n*. [`sharded::ShardedRmq`] turns that
//! observation into an architecture: partition the array into `B`-sized
//! blocks, give each block its own solver, and keep a summary solver
//! over the per-block minima. Every query then decomposes into at most
//! two partial-block probes plus one summary probe — all of them in the
//! small-range regime *by construction*, independent of the original
//! range length. Construction parallelises trivially over blocks
//! (`util::pool`), and the summary array is `n/B` long, so both levels
//! stay within the flat-geometry precision budget (§5.2, n ≤ 2^24).
//!
//! The same decomposition is what makes **mutable arrays** servable
//! (ROADMAP north star; RT-HDIST shows RT structures tolerate
//! incremental rebuilds): a point update touches exactly one block —
//! re-shape its triangles, refit its BVH (the `bvh/wide.rs` refit path),
//! rescan one block minimum, refit the summary. `update_batch` groups
//! updates by block so each touched structure refits once per batch,
//! and the per-block refits run **in parallel** over `util::pool` (they
//! are independent; only the summary refit joins, so the result is
//! bit-identical for any worker count).
//! Tie-breaks remain leftmost end to end: candidate index order is
//! left partial < summary interior < right partial, later candidates
//! must win *strictly*, the summary prefers the leftmost minimal block,
//! and `block_argmin` stores the leftmost argmin within each block.
//!
//! # Mutable serving (design note)
//!
//! The coordinator serves *mixed op streams* (`workload::Op`: queries
//! and point updates, `workload::gen_mixed` is the synthetic source)
//! end to end:
//!
//! - **Fencing semantics.** The batcher flattens requests in arrival
//!   order and cuts the op stream into maximal same-kind *segments*
//!   (`coordinator::batcher::Segment`). The single serving thread
//!   executes segments strictly in stream order, so an update segment
//!   is a fence: its values are visible to every later query segment
//!   (including queries of later-arriving requests fused into the same
//!   batch) and to none earlier. At the engine level the sharded
//!   solver sits behind a `RwLock` — queries share the read lock, an
//!   update batch takes the write lock — so a reader can never observe
//!   a half-applied batch. Differential tests pin this against a naive
//!   array + rescan oracle (`tests/mixed_stream.rs`).
//! - **Auto-tuned block size.** `--shard-block auto` replaces the √n
//!   rule with the argmin of `RtCostModel::shard_cost_per_op(n, B)`:
//!   expected probe work at the expected range distribution
//!   (`min(span, 2)` partial-block probes of `O(log B)` work plus a
//!   summary probe of `O(log n/B)` once the span passes two blocks)
//!   plus the update fraction times the amortised refit work
//!   (`Θ(B)` block refit + `Θ(n/B)` summary refit — and the summary
//!   term is point-refit away for single-min batches, see below). The
//!   candidate set contains the √n default, so the tuned size never
//!   models worse. The CLI `--dist`/`--update-frac` only seed the
//!   *initial* build; under serving, the tuner re-runs against
//!   observed traffic (next note).
//!
//! # Epoch lifecycle (design note)
//!
//! Updates mutate only the sharded engine; every static engine (RTX
//! wide-BVH, LCA, HRMQ, EXHAUSTIVE, XLA) keeps the array it was built
//! from. Engines therefore live in **epochs**
//! (`coordinator::engine::EngineEpoch`) with these invariants:
//!
//! - An epoch is immutable: `version`, its engine set, and
//!   `built_from_seq` — the applied-update sequence number its static
//!   engines were built from. The sharded engine is shared across
//!   epochs by `Arc` and is *always current*: its seq is bumped under
//!   the same write lock that applies the batch, so a read-locked
//!   (values, seq) snapshot is consistent by construction.
//! - **Freshness, not history, routes queries.** A query segment pins
//!   the current epoch (`Arc` clone) and asks
//!   `Router::route_epoch(…, fresh)` where `fresh ⇔ built_from_seq ==
//!   live seq`. Stale ⇒ availability collapses to the sharded engine;
//!   fresh ⇒ every policy routes normally. This is why `Policy::Fixed`
//!   no longer needs a staleness *override*: staleness is an
//!   availability rule applied uniformly before any policy runs, and —
//!   unlike the old sticky `mutated` flag, which out-pinned a Fixed
//!   policy forever — it clears the moment a rebuilt epoch is
//!   published, at which point the pin is honored verbatim again.
//! - **Rebuild trigger.** The serving thread feeds a decayed traffic
//!   observer (`workload::observer`) per segment and calls
//!   `EpochState::plan` per fused batch. Once the epoch is stale *and*
//!   the observed update rate drops below
//!   `RtCostModel::rebuild_worthwhile`'s threshold (expected queries
//!   before the next staleness, times the per-query routing gain,
//!   must cover the modeled rebuild cost), a background builder
//!   snapshots the sharded engine, rebuilds the statics, and publishes
//!   the new epoch with an atomic swap. In-flight segments finish on
//!   the epoch they pinned; later segments route freely again (the
//!   Fig. 12 crossover comes back).
//! - **Re-shard trigger.** Under `--shard-block auto`, `plan` also
//!   re-runs the tuner against the observed range-length histogram;
//!   when the tuned block size drifts ≥ `--reshard-drift` (default 2×)
//!   from the live one, the builder re-shards from a snapshot and
//!   swaps the new decomposition in iff no update batch landed in
//!   between (a moved seq aborts the swap; `plan` retries when quiet).
//! - **Summary point-refit.** An update batch that changes exactly one
//!   block minimum re-shapes that one summary triangle and refits its
//!   leaf-to-root path (`RtxRmq::update_values_point`) instead of
//!   sweeping the whole summary structure — the Θ(n/B) per-batch term
//!   the cost model charges becomes an upper bound realised only by
//!   multi-block batches. The same route now applies one level down: a
//!   block that received exactly **one** update path-refits its block
//!   BVH and maintains its min table in O(1) (rescan only when the old
//!   argmin's value rose), and `RtCostModel::shard_update_work` charges
//!   update batches by their observed shape — single-point batches cost
//!   two path refits, not `B + n/B`.
//!
//! # Instanced block geometry & compressed leaves (design note)
//!
//! The sharded engine's per-block BVHs were structurally identical: a
//! `B`-element block's tree shape depends only on `B`, never on the
//! values. `ShardBackend::Instanced` (the default) exploits that the
//! way RT hardware instancing does — build **one positional shape tree
//! per unique block length** (`bvh::instanced::ShapeTree`, a balanced
//! 4-wide interval tree over `[0, len)` with `u16` slot bounds) and
//! store per-block data as an *instance*: a value offset/scale pair
//! plus a compact leaf table.
//!
//! - **Shape-cache keying.** `ShapeSet` keys shared trees by block
//!   *length* alone — an array of `nb` blocks holds at most three
//!   distinct shapes (the interior length `B`, the tail length
//!   `n mod B`, and the summary length `nb`), each `Arc`-shared by
//!   every instance of that length. Shape bytes are counted once at
//!   the `ShardedRmq` level, never per block: that is the entire
//!   memory story. `u16` slot indices cap instanced lengths at 2^16;
//!   a summary over more blocks than that falls back to a sparse
//!   table (`ShardedRmq::with_options`).
//! - **Compressed leaf records.** The non-instanced path spends 24
//!   bytes per element on `WidePrim` leaves (plus ~2× that in wide
//!   nodes). An instance spends ~6: a `u16` quantized value per
//!   element (`qval`) plus 8 bytes of bucketed lane minima per shape
//!   node (`node_qmin`). Values quantize block-relative — `q =
//!   (v − v_lo) / scale`, floor-rounded with a guard loop so
//!   `dequant(q) ≤ v` always — which keeps every quantized bound a
//!   *lower* bound of the exact values it summarizes.
//! - **Probe-time value translation.** Quantized fields only *screen*:
//!   traversal descends a lane when its bucketed minimum could still
//!   beat the incumbent, but every accept resolves the **exact f32**
//!   from the caller's value slice (the solver-owned `xs` block) before
//!   it updates the incumbent. The quantized tables never decide a
//!   comparison between two candidates — they only rule lanes out.
//! - **Why leftmost ties survive quantization.** Work items are pushed
//!   in reverse lane order so the stack pops strictly left-to-right,
//!   and both the descend test and the accept test are *strict* `<`
//!   against the incumbent's exact value. Two positions in the same
//!   quantization bucket therefore tie exactly as their f32 values
//!   tie, and the earlier position wins because it is examined first —
//!   the same argument as the non-instanced traversal, pinned at
//!   bucket boundaries by `tests/instanced_diff.rs`.
//! - **Updates without rebuilds.** A point update is a leaf-table
//!   write (`InstancedBlock::refit_point`: requantize one slot, walk
//!   its ancestor lane minima) — no tree to rebuild, because the tree
//!   is *positional* and shared. A value dropping below `v_lo` lowers
//!   the offset in place; bounds get looser, never wrong. Staged
//!   replacement blocks (`StagedUpdateSpec`) are an O(B) quantize pass
//!   against the cached shape, which is why `RtCostModel::c_inst`
//!   prices staging-lane work as refit-shaped rather than build-shaped.
//!
//! # Overlapped update/query pipeline (design note)
//!
//! The serial executor made every update segment a full pipeline stall:
//! finish query segment k−1, refit, resume. The serving loop now runs a
//! **two-lane pipeline** (`coordinator::server`):
//!
//! - **Why overlapping with the *preceding* segment is safe.** The
//!   fence semantics only constrain *later* queries — segment k−1 must
//!   not see update segment k's values, and preparation never writes.
//!   Staging computes per-block *replacement* solvers from a
//!   read-locked snapshot (`ShardedRmq::stage_update_batch` copies the
//!   touched block slices with the updates applied; `StagedUpdateSpec::
//!   build` constructs solvers with no lock held), so queries of
//!   segment k−1 keep reading the live, pre-fence structure while the
//!   refit work runs. The batcher annotates each update segment with
//!   the query segment it may overlap (`FusedBatch::overlap_with` —
//!   always the direct predecessor; a leading update segment has
//!   nothing to hide behind and applies directly).
//! - **The prepare/commit seq protocol.** A preparation records the
//!   mutable engine's (applied-update seq, shape generation) under the
//!   same read lock that snapshots the blocks. At the fence,
//!   `ShardedEngine::commit_prepared` takes the write lock and installs
//!   the prepared blocks **iff both still match** — a moved seq means a
//!   conflicting update batch landed (the prepared blocks embed stale
//!   values), a moved shape generation means a background re-shard
//!   swapped the decomposition (block ids no longer line up). Either
//!   conflict voids the preparation and the batch is applied through
//!   the ordinary direct path under the same lock. Both outcomes bump
//!   the seq exactly once, so results are bit-identical to serial
//!   execution for any overlap timing — the differential suite
//!   (`tests/mixed_stream.rs`) and the no-toolchain simulation
//!   (`epoch_sim.py`) pin pipelined vs sequential-oracle execution
//!   across fence-heavy streams, conflicts included.
//! - **Interaction with epoch staleness.** The observer feed and
//!   `EpochState::plan` stay at *commit* points: an update segment
//!   bumps the seq when it commits (not when it stages), so epochs read
//!   as stale at exactly the same stream positions as under serial
//!   execution, and in-flight query segments still pin their epoch as
//!   in the lifecycle design above. The `pipeline` metrics line
//!   (`overlap_ns_hidden`) reports how much preparation latency the
//!   overlap actually removed from the serving thread's critical path.
//!
//! # Failure model & graceful degradation (design note)
//!
//! The serving stack runs real threads (batcher-fed serving loop,
//! staged-prepare worker, background builder, pool workers); the failure
//! model says what each one is allowed to do when code panics, and what
//! clients may observe. Three rules:
//!
//! - **Absorb at source.** Every lifecycle thread is panic-isolated at
//!   its own boundary (`catch_unwind` + the poison-recovering locks in
//!   `util::sync`), and recovery happens at the layer that owns the
//!   state. A panicked pool worker's chunk is re-run inline by the
//!   caller (`util::pool` — same closure, same slice, bit-identical).
//!   A dead staged preparation makes the fence fall back to the direct
//!   apply path (the same path a commit conflict takes). A panic inside
//!   a direct apply is caught after the values landed but before the
//!   refit — recovery rebuilds the touched structures *from the stored
//!   values*, so the batch is never half-visible. A dead background
//!   builder clears its claimed job and respawns with exponential
//!   backoff; the lifecycle simply reschedules. A panic at the batcher
//!   hand-off drops the pulled group before any segment executes.
//! - **Accepted implies exact; rejected implies no effect.** Clients
//!   see a typed result (`batcher::ServeError`): `Overloaded` when the
//!   queue-depth gauge passes the shed watermark (admission control,
//!   checked before enqueue), `DeadlineExceeded` when a request's
//!   deadline lapses in the queue (dropped whole at batch-build time,
//!   before any of its ops execute), `Failed` when the serving loop's
//!   last-resort backstop caught a genuine bug. In every case the
//!   rejection is *whole-request*: no partial stream executes, so the
//!   differential contract survives — under any fault schedule, every
//!   **accepted** request's answers are bit-identical to the fault-free
//!   sequential oracle (the chaos suite in `tests/mixed_stream.rs`
//!   pins this; `faults_sim.py` mirrors the protocol sans toolchain).
//! - **Deterministic chaos.** `util::faults` is a process-global
//!   registry of named injection sites (`serve --inject
//!   "site:kind:prob:count"`, seeded RNG per rule) compiled into every
//!   build: one relaxed atomic load when disarmed, so production pays
//!   nothing. Panics injected at a site are indistinguishable from
//!   organic panics at that boundary — the recovery paths above are
//!   exercised, counted (`faults` metrics line: injected, caught,
//!   lock-recovered, respawns, fallbacks, shed, expired), and asserted
//!   against the oracle. `panic` at `stage.commit` is rejected by the
//!   parser (a commit panic could strand a half-applied batch); the
//!   `err` kind forces the conflict-fallback path instead.
//!
//! # Multi-tenant scheduling & QoS (design note)
//!
//! One process serves many named arrays (`coordinator::tenants`): each
//! tenant owns the full per-array stack above — epoch lifecycle,
//! observer, sharded engine, metrics, fault counters — and the only
//! shared pieces are threads: a small work-stealing executor and one
//! background builder pool. The scheduling contract:
//!
//! - **One FIFO queue per tenant, class at the head.** Requests are
//!   classified once at admission — *interactive* iff query-only with
//!   mean range length ≤ the tenant's ceiling (default √n, the paper's
//!   small-range sweet spot) — and a tenant's current class is its
//!   queue head's class. Keeping each tenant strictly FIFO is what
//!   makes the per-tenant differential oracle valid: answers are
//!   bit-identical to a dedicated single-array coordinator regardless
//!   of cross-tenant interleaving (`tests/tenant_isolation.rs`).
//! - **Two-pass weighted-deficit pick.** Idle executor workers scan
//!   interactive-headed tenants strictly before bulk-headed ones, so
//!   small-range traffic is never queued behind another tenant's
//!   update/rebuild work; within a class, each scan adds the tenant's
//!   weight to a deficit counter and the largest deficit wins (reset on
//!   pick) — weights share the executor proportionally without
//!   starving anyone. A per-tenant claim (CAS) keeps execution serial
//!   per array (the fence ordering survives) while workers steal
//!   freely across tenants.
//! - **Layered admission.** A global queued-request watermark sheds
//!   before any per-tenant watermark is consulted; per-tenant
//!   `--shed-watermark`/`--deadline-ms` keep one tenant's burst from
//!   consuming the process. Batches drain only consecutive same-class
//!   requests, so a class flip splits the batch instead of smuggling
//!   bulk work into an interactive pick.
//! - **Shared builder, isolated failures.** Rebuild/re-shard jobs from
//!   every tenant funnel through one builder pool with *per-tenant*
//!   panic backoff; an executor-batch kill (`tenant.exec` fault site,
//!   fired before any segment executes) fails that batch atomically —
//!   no update applies, every other tenant's counters and answers stay
//!   untouched. The nightly 3-tenant chaos soak pins the QoS claim
//!   end-to-end: a flooding bulk tenant saturates its own watermark
//!   (shed > 0) while the interactive tenant finishes with
//!   shed = expired = 0.
//!
//! # Lazy range tags (design note)
//!
//! Range updates — `add v` / `assign v` over `[l, r]`
//! (`workload::Op::RangeAdd` / `RangeAssign`, `--range-frac` in the
//! mixed generators) — ride the same block decomposition that makes
//! point updates cheap, with one extra idea: a **fully covered block
//! never rebuilds its structure**. `ShardedRmq::range_update` splits
//! the span into at most two partial boundary blocks plus the covered
//! interior, and treats the two cases asymmetrically:
//!
//! - **Covered blocks take a lazy tag.** The instanced leaf table
//!   stores quantized values as `v_lo + q·scale`, so a uniform `add v`
//!   is a pure transform shift: `InstancedBlock::apply_add` moves
//!   `v_lo` in place — O(1) per block, no requantize, no tree work
//!   (a bounded excess sweep re-tightens the floor if prior updates
//!   left slack; `tag_hits` counts exactly these O(1) absorptions). A
//!   covered `assign v` collapses the block to a constant:
//!   `apply_assign` sets `{v_lo = v, scale = 0}`, every probe resolves
//!   the exact constant, and the first later point write re-opens the
//!   block through the `scale ≤ 0` rebuild arm of `refit_point`. Only
//!   the *structures* are lazy — the solver-owned `xs` values are
//!   rewritten eagerly, which is why probe-time exact-value resolution,
//!   snapshots, and staged-spec builds need no tag-awareness at all.
//! - **Boundary blocks requantize.** A partially covered block gets
//!   its sub-range written and its table rebuilt against the cached
//!   shape (the same O(B) refit-shaped pass staged replacements use),
//!   then a one-block rescan refreshes its (min, argmin). The summary
//!   then refits from the changed block minima — the single-min path
//!   refit when exactly one block moved, the full sweep otherwise.
//!
//! Fencing, staging, and recovery all treat range segments as update
//! segments: the batcher fences them identically, and a segment
//! containing any range op stages as a **pointer-sized tag spec**
//! (`has_range`, no prebuilt blocks) that commits by replaying the ops
//! under the write lock iff the (seq, shape generation) fingerprint
//! still holds — covered-block work is O(1) per block, so there is
//! nothing worth precomputing off-thread. Direct applies snapshot the
//! union span *before* writing because a range `add` is not idempotent:
//! panic recovery restores the span, then replays. The cost model
//! prices all of it (`RtCostModel::range_update_work`, in `c_inst`
//! units): tagged blocks at O(1), boundaries at refit shape — which is
//! why long spans are *cheaper* per element than their point
//! decomposition, the claim `tests/range_update_diff.rs` pins with
//! exact `tag_hits` equalities alongside the bit-identical differential
//! against the naive oracle (`range_sim.py` mirrors it sans toolchain).

pub mod cartesian;
pub mod exhaustive;
pub mod hrmq;
pub mod lca;
pub mod rtx;
pub mod sharded;
pub mod sparse_table;

use crate::util::pool;

/// A query: inclusive (l, r) index pair.
pub type Query = (u32, u32);

/// Common interface for every RMQ approach.
pub trait RmqSolver: Send + Sync {
    /// Short identifier used in bench output ("RTXRMQ", "HRMQ", "LCA", ...).
    fn name(&self) -> &'static str;

    /// Answer one query; `l ≤ r < n`. Returns the index of the leftmost
    /// minimum in `[l, r]`.
    fn rmq(&self, l: u32, r: u32) -> u32;

    /// Answer a batch of queries, parallelised over `workers` threads.
    /// This is the paper's execution model: all approaches are evaluated
    /// on *batches* of RMQs (§1, §6).
    fn batch(&self, queries: &[Query], workers: usize) -> Vec<u32> {
        let mut out = vec![0u32; queries.len()];
        pool::for_each_chunk_mut(&mut out, workers, |off, slice| {
            for (k, o) in slice.iter_mut().enumerate() {
                let (l, r) = queries[off + k];
                *o = self.rmq(l, r);
            }
        });
        out
    }

    /// Resident bytes of everything this solver owns (paper Table 2,
    /// plus the bench harness's `resident_bytes` column). Solver-held
    /// *copies* of the input array count — the instanced sharded engine
    /// resolves exact values from its own `xs` at probe time, so that
    /// copy is load-bearing, not bookkeeping. The caller's original
    /// array is the only thing excluded.
    fn memory_bytes(&self) -> usize;
}

/// Validate queries against the array length (used by the coordinator's
/// admission check).
pub fn validate_queries(n: usize, queries: &[Query]) -> Result<(), String> {
    for (i, &(l, r)) in queries.iter().enumerate() {
        if l > r || (r as usize) >= n {
            return Err(format!("query {i} = ({l},{r}) invalid for n={n}"));
        }
    }
    Ok(())
}

/// Reference scan used in tests (independent of any solver).
pub fn naive_rmq(xs: &[f32], l: usize, r: usize) -> usize {
    let mut best = l;
    for k in l + 1..=r {
        if xs[k] < xs[best] {
            best = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_prefers_leftmost() {
        let xs = [3.0, 1.0, 2.0, 1.0];
        assert_eq!(naive_rmq(&xs, 0, 3), 1);
        assert_eq!(naive_rmq(&xs, 2, 3), 3);
        assert_eq!(naive_rmq(&xs, 2, 2), 2);
    }

    #[test]
    fn validate_queries_rejects_bad() {
        assert!(validate_queries(4, &[(0, 3)]).is_ok());
        assert!(validate_queries(4, &[(2, 1)]).is_err());
        assert!(validate_queries(4, &[(0, 4)]).is_err());
    }
}

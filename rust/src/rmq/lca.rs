//! LCA-based RMQ — the paper's GPU state-of-the-art baseline (Polak,
//! Siwiec, Stobierski, IPDPS 2021), which answers RMQ through the dual
//! problem: `RMQ(l, r) = LCA(node_l, node_r)` on the Cartesian tree.
//! Polak et al. implement the Schieber–Vishkin *inline* LCA algorithm
//! [SIAM J. Comput. 1988] with Euler-tour preprocessing; we implement the
//! same O(n) preprocessing / O(1) inline query, batch-parallel over
//! queries (their GPU grid maps to our worker pool; the GPU *timing* is
//! produced by the cost model in `crate::model`).
//!
//! Schieber–Vishkin in brief: nodes get 1-based preorder numbers; each
//! node's `inlabel` is the number with the most trailing zeros inside its
//! preorder interval, which decomposes the tree into O(n/2^k) paths per
//! level k; `ascendant` masks record which inlabel levels appear on each
//! node's root path, and `head` maps an inlabel to the highest node of its
//! path. Queries then run in O(1) with word-level bit tricks.

use super::cartesian::{CartesianTree, NIL};
use super::RmqSolver;

/// Index of the most significant set bit.
#[inline]
fn msb(x: u32) -> u32 {
    debug_assert!(x != 0);
    31 - x.leading_zeros()
}

/// Index of the least significant set bit.
#[inline]
fn lsb(x: u32) -> u32 {
    debug_assert!(x != 0);
    x.trailing_zeros()
}

/// Schieber–Vishkin LCA structure over a Cartesian tree.
pub struct LcaRmq {
    parent: Vec<u32>,
    depth: Vec<u32>,
    inlabel: Vec<u32>,
    ascendant: Vec<u32>,
    /// head[inlabel] = node closest to the root having that inlabel.
    head: Vec<u32>,
}

impl LcaRmq {
    pub fn new(xs: &[f32]) -> LcaRmq {
        let tree = CartesianTree::build(xs);
        Self::from_tree(&tree)
    }

    pub fn from_tree(tree: &CartesianTree) -> LcaRmq {
        let n = tree.len();
        let depth = tree.depths();
        let (pre, order) = tree.preorder();
        let size = tree.subtree_sizes(&order);

        // inlabel(v): i = pre(v), j = i + size(v) - 1. The number in
        // [i, j] with the most trailing zeros is obtained by clearing the
        // low bits of j below the highest bit where (i-1) and j differ.
        let mut inlabel = vec![0u32; n];
        for v in 0..n {
            let i = pre[v];
            let j = i + size[v] - 1;
            inlabel[v] = if i == j {
                i
            } else {
                let k = msb((i - 1) ^ j);
                (j >> k) << k
            };
        }

        // ascendant masks accumulate down the tree in preorder (the level
        // of an inlabel is its number of trailing zeros).
        let mut ascendant = vec![0u32; n];
        for &v in &order {
            let v = v as usize;
            let bit = 1u32 << lsb(inlabel[v]);
            let p = tree.parent[v];
            ascendant[v] = if p == NIL { bit } else { ascendant[p as usize] | bit };
        }

        // head of each inlabel path: the node whose parent has a
        // different inlabel (or the root).
        let mut head = vec![NIL; n + 1];
        for &v in &order {
            let v = v as usize;
            let p = tree.parent[v];
            if p == NIL || inlabel[p as usize] != inlabel[v] {
                head[inlabel[v] as usize] = v as u32;
            }
        }

        LcaRmq { parent: tree.parent.clone(), depth, inlabel, ascendant, head }
    }

    /// Closest ancestor of `x` (inclusive) whose inlabel equals
    /// `inlabel_z` (the LCA's inlabel), given `j = level(inlabel_z)`.
    #[inline]
    fn climb(&self, x: u32, inlabel_z: u32, j: u32) -> u32 {
        let xi = x as usize;
        if self.inlabel[xi] == inlabel_z {
            return x;
        }
        // Highest inlabel level on x's root path strictly below level j.
        let below = self.ascendant[xi] & ((1u32 << j) - 1);
        debug_assert!(below != 0, "x must have a path level below the lca's");
        let k = msb(below);
        // inlabel of x's ancestor path at level k: clear inlabel(x)'s low
        // bits below k, set bit k.
        let inlabel_w = ((self.inlabel[xi] >> (k + 1)) << (k + 1)) | (1u32 << k);
        let w = self.head[inlabel_w as usize];
        debug_assert!(w != NIL);
        self.parent[w as usize]
    }

    /// O(1) LCA query.
    #[inline]
    pub fn lca(&self, x: u32, y: u32) -> u32 {
        let (ix, iy) = (self.inlabel[x as usize], self.inlabel[y as usize]);
        if ix == iy {
            // Same path: the shallower node is the ancestor.
            return if self.depth[x as usize] <= self.depth[y as usize] { x } else { y };
        }
        // Lowest common inlabel level at or above where the labels differ.
        let i = msb(ix ^ iy);
        let common = self.ascendant[x as usize] & self.ascendant[y as usize];
        let j = lsb(common & (u32::MAX << i));
        let inlabel_z = ((ix >> (j + 1)) << (j + 1)) | (1u32 << j);
        let xp = self.climb(x, inlabel_z, j);
        let yp = self.climb(y, inlabel_z, j);
        if self.depth[xp as usize] <= self.depth[yp as usize] {
            xp
        } else {
            yp
        }
    }
}

impl RmqSolver for LcaRmq {
    fn name(&self) -> &'static str {
        "LCA"
    }

    #[inline]
    fn rmq(&self, l: u32, r: u32) -> u32 {
        // RMQ(l, r) = LCA of the two endpoint nodes in the Cartesian tree.
        self.lca(l, r)
    }

    fn memory_bytes(&self) -> usize {
        (self.parent.len() + self.depth.len() + self.inlabel.len() + self.ascendant.len()) * 4
            + self.head.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::sparse_table::SparseTable;
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let s = LcaRmq::new(&xs);
        assert_eq!(s.rmq(2, 6), 5);
        assert_eq!(s.rmq(0, 6), 5);
        assert_eq!(s.rmq(0, 3), 1);
        assert_eq!(s.rmq(6, 6), 6);
    }

    #[test]
    fn exhaustive_small_n() {
        let mut state = 1234u64;
        for n in 1..=40usize {
            let xs: Vec<f32> = (0..n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 6) as f32)
                .collect();
            let s = LcaRmq::new(&xs);
            for l in 0..n {
                for r in l..n {
                    assert_eq!(
                        s.rmq(l as u32, r as u32) as usize,
                        naive_rmq(&xs, l, r),
                        "n={n} l={l} r={r} xs={xs:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn lca_matches_naive_walk() {
        check("SV lca vs parent walk", 80, |rng| {
            let xs = gen::f32_array(rng, 2..=512);
            let tree = CartesianTree::build(&xs);
            let depth = tree.depths();
            let s = LcaRmq::from_tree(&tree);
            for _ in 0..32 {
                let u = rng.range(0, xs.len() - 1) as u32;
                let v = rng.range(0, xs.len() - 1) as u32;
                let got = s.lca(u, v);
                let want = tree.lca_naive(u, v, &depth);
                if got != want {
                    return Err(format!("lca({u},{v}) = {got}, want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_vs_oracle_large() {
        check("SV rmq vs sparse table", 80, |rng| {
            let xs = gen::f32_array(rng, 1..=8192);
            let s = LcaRmq::new(&xs);
            let st = SparseTable::new(&xs);
            for _ in 0..48 {
                let (l, r) = gen::query(rng, xs.len());
                let (got, want) = (s.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32));
                if got != want {
                    return Err(format!("({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_adversarial_paths() {
        // Deep path-shaped trees stress the inlabel/ascendant machinery.
        check("SV on sorted/reverse/sawtooth", 60, |rng| {
            let xs = gen::adversarial_array(rng, 2..=2048);
            let s = LcaRmq::new(&xs);
            let st = SparseTable::new(&xs);
            for _ in 0..32 {
                let (l, r) = gen::query(rng, xs.len());
                if s.rmq(l as u32, r as u32) != st.rmq(l as u32, r as u32) {
                    return Err(format!("mismatch at ({l},{r})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn duplicates_leftmost() {
        check("SV leftmost ties", 60, |rng| {
            let xs = gen::dup_array(rng, 1..=1024, 2);
            let s = LcaRmq::new(&xs);
            for _ in 0..24 {
                let (l, r) = gen::query(rng, xs.len());
                let want = naive_rmq(&xs, l, r);
                let got = s.rmq(l as u32, r as u32) as usize;
                if got != want {
                    return Err(format!("({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_linear_words() {
        let xs = crate::util::rng::Rng::new(21).uniform_f32_vec(1 << 12);
        let s = LcaRmq::new(&xs);
        // 4 arrays of n u32 + head of (n+1) u32
        assert_eq!(s.memory_bytes(), 4 * (1 << 12) * 4 + ((1 << 12) + 1) * 4);
    }
}

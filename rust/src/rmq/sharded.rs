//! Two-level sharded RMQ — blocked decomposition that *manufactures* the
//! paper's winning regime (Fig. 10: RTXRMQ dominates when ranges are
//! small relative to the problem size).
//!
//! The array is partitioned into `B`-sized blocks, each backed by its own
//! per-block solver (an RTXRMQ flat-geometry wide-BVH by default, the
//! sparse table as the cheap oracle backend), plus a *summary* solver
//! over the per-block minima. Any query `(l, r)` then decomposes into at
//! most three probes, **all of them small-range by construction**:
//!
//! ```text
//!   [ .. | l..    | full blocks ... | ..r | .. ]
//!          ^left partial ^summary probe ^right partial
//! ```
//!
//! Tie-breaks stay leftmost end to end: the left probe wins ties against
//! the summary, which wins ties against the right probe (candidate index
//! order is left < interior < right), the summary solver itself prefers
//! the leftmost minimal *block*, and `block_argmin[b]` is the leftmost
//! argmin inside block `b`.
//!
//! This is also the repo's first **mutable-array** subsystem:
//! [`ShardedRmq::update_batch`] applies point updates by re-shaping the
//! touched triangles of each affected block, refitting that block's BVH
//! once (the refit path `bvh/wide.rs` property-tests), rescanning the
//! block minimum, and refitting the summary — no global rebuild.
//! Construction is parallelised over blocks via [`crate::util::pool`].

use super::rtx::{RtxMode, RtxOptions, RtxRmq, RtxScratch};
use super::sparse_table::SparseTable;
use super::{Query, RmqSolver};
use crate::bvh::instanced::{InstancedBlock, ShapeSet, MAX_INSTANCED_LEN, SHAPE_LEAF_SIZE};
use crate::bvh::traverse::Counters;
use crate::bvh::AccelLayout;
use crate::util::pool;
use crate::workload::UpdateOp;
use std::collections::BTreeMap;

/// Lifetime range-update counters of one decomposition ("Lazy range
/// tags" design note, `rmq/mod.rs`). `tag_hits` counts fully-covered
/// instanced blocks absorbed by a `v_lo` shift or a constant-block
/// collapse — i.e. with **no** requantize and no node work — so the
/// O(1)-per-covered-block claim is checkable, not just asserted.
/// Carried across re-shards/installs via
/// [`ShardedRmq::adopt_range_stats`] so metrics stay monotone.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RangeStats {
    /// Range ops applied (`add` + `assign`).
    pub range_updates: u64,
    /// Covered instanced blocks that took the lazy-tag path.
    pub tag_hits: u64,
}

/// Which solver backs each block (and the summary).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardBackend {
    /// Instanced geometry (default): one shared shape tree per unique
    /// block length plus a compressed per-block leaf table — see the
    /// design note in [`crate::bvh::instanced`]. Point updates refit the
    /// instance tables in place; no per-block tree exists to rebuild.
    /// Block size is capped at `MAX_INSTANCED_LEN` (u16 positions).
    #[default]
    Instanced,
    /// RTXRMQ flat geometry per block (the paper's solver, in the regime
    /// it wins). Updates refit in place.
    Rtx,
    /// Sparse table per block (oracle backend; updates rebuild the
    /// touched block — blocks are small, so this stays cheap).
    Sparse,
}

impl ShardBackend {
    pub fn name(&self) -> &'static str {
        match self {
            ShardBackend::Instanced => "instanced",
            ShardBackend::Rtx => "rtx",
            ShardBackend::Sparse => "sparse",
        }
    }
}

/// Build-time options.
#[derive(Clone, Copy, Debug)]
pub struct ShardedOptions {
    /// Elements per block; 0 = auto (≈√n, power of two, clamped).
    pub block_size: usize,
    /// Acceleration layout of every per-block / summary BVH (Rtx backend).
    pub layout: AccelLayout,
    /// Per-block solver kind.
    pub backend: ShardBackend,
    /// Walk each worker chunk in left-endpoint order (same coherence
    /// trade as [`RtxOptions::sort_queries`]).
    pub sort_queries: bool,
    /// Threads used to build blocks; 0 = `pool::default_workers()`.
    pub build_workers: usize,
    /// Probe this many same-target ranges per shared descent (`0` =
    /// scalar). The batch driver decomposes each chunk's queries into
    /// block and summary probes, groups consecutive same-block runs
    /// into packets, and resolves them through the backend's packet
    /// entry — answers are bit-identical at every width (probes are
    /// independent; the strict-`<` combination is unchanged).
    pub packet_width: usize,
}

impl Default for ShardedOptions {
    fn default() -> Self {
        ShardedOptions {
            block_size: 0,
            layout: AccelLayout::Wide,
            backend: ShardBackend::default(),
            sort_queries: true,
            build_workers: 0,
            packet_width: 0,
        }
    }
}

/// √n-balanced power-of-two block size (clamped so tiny arrays collapse
/// to a single block and huge arrays keep per-block scenes cache-sized).
pub fn auto_block_size(n: usize) -> usize {
    let root = (n as f64).sqrt().round().max(1.0) as usize;
    root.next_power_of_two().clamp(4, 1 << 12)
}

/// One block's solver. Local indices in `[0, block_len)`.
enum BlockSolver {
    Instanced(InstancedBlock),
    Rtx(RtxRmq),
    Sparse(SparseTable),
}

impl BlockSolver {
    /// `shapes` must already hold the shape for `xs.len()` when the
    /// backend is instanced ([`ShapeSet::ensure`] runs before every
    /// parallel build loop — the loops share the set immutably).
    fn build(xs: &[f32], opts: &ShardedOptions, shapes: &ShapeSet) -> BlockSolver {
        match opts.backend {
            ShardBackend::Instanced => {
                BlockSolver::Instanced(InstancedBlock::build(xs, shapes.get(xs.len()).clone()))
            }
            ShardBackend::Rtx => BlockSolver::Rtx(RtxRmq::with_options(
                xs,
                RtxOptions { mode: RtxMode::Flat, layout: opts.layout, ..Default::default() },
            )),
            ShardBackend::Sparse => BlockSolver::Sparse(SparseTable::new(xs)),
        }
    }

    /// `xs_block` is the solver's exact value slice (block slice of the
    /// engine's value array; `block_min` for the summary) — the
    /// instanced probe resolves exact values from it on hit.
    #[inline]
    fn rmq_local(
        &self,
        xs_block: &[f32],
        l: u32,
        r: u32,
        scratch: &mut RtxScratch,
        c: &mut Counters,
    ) -> u32 {
        match self {
            BlockSolver::Instanced(s) => s.probe(xs_block, l as usize, r as usize, c) as u32,
            BlockSolver::Rtx(s) => s.rmq_counted(l, r, scratch, c),
            BlockSolver::Sparse(s) => s.rmq(l, r),
        }
    }

    /// Packet analogue of [`rmq_local`](Self::rmq_local): resolve a
    /// group of local ranges over this one solver in a shared descent
    /// where the backend supports it (the instanced packet probe, the
    /// flat-geometry wide packet); the sparse oracle stays scalar.
    /// Bit-identical to per-range `rmq_local` calls for every group.
    fn rmq_local_packet(
        &self,
        xs_block: &[f32],
        ranges: &[(u32, u32)],
        out: &mut [u32],
        scratch: &mut RtxScratch,
        c: &mut Counters,
    ) {
        debug_assert_eq!(ranges.len(), out.len());
        match self {
            BlockSolver::Instanced(s) => {
                let rs: Vec<(usize, usize)> =
                    ranges.iter().map(|&(l, r)| (l as usize, r as usize)).collect();
                let mut local = vec![0usize; rs.len()];
                s.probe_packet(xs_block, &rs, &mut local, c);
                for (o, v) in out.iter_mut().zip(local) {
                    *o = v as u32;
                }
            }
            BlockSolver::Rtx(s) => s.rmq_group_packet(ranges, out, scratch, c),
            BlockSolver::Sparse(s) => {
                for (o, &(l, r)) in out.iter_mut().zip(ranges) {
                    *o = s.rmq(l, r);
                }
            }
        }
    }

    /// Apply local point updates. `fresh` is the block's full value slice
    /// *after* the updates (rebuild source for the sparse backend and
    /// requantization source for the instanced one).
    fn update(&mut self, local: &[(usize, f32)], fresh: &[f32]) {
        match self {
            BlockSolver::Instanced(s) => s.rebuild_values(fresh),
            BlockSolver::Rtx(s) => s.update_values(local),
            BlockSolver::Sparse(s) => *s = SparseTable::new(fresh),
        }
    }

    /// Point-update fast path for sparse batches: the instanced backend
    /// writes the leaf record and walks its lane-min path (`O(leaf +
    /// 4·depth)`, no tree work at all); the Rtx backend re-shapes the
    /// touched triangles and refits only their ancestor paths (Θ(k·log
    /// n) vs the full sweep's Θ(n)); the sparse backend has no refit
    /// path and rebuilds as before.
    fn update_point(&mut self, local: &[(usize, f32)], fresh: &[f32]) {
        match self {
            BlockSolver::Instanced(s) => {
                for &(j, v) in local {
                    s.refit_point(j, v, fresh);
                }
            }
            BlockSolver::Rtx(s) => s.update_values_point(local),
            BlockSolver::Sparse(s) => *s = SparseTable::new(fresh),
        }
    }

    /// Bytes owned by this solver alone. For the instanced backend that
    /// is just the compressed instance tables — the shared shape trees
    /// are counted once at the [`ShardedRmq`] level (`ShapeSet`), not
    /// per block: that is the entire point of instancing.
    fn memory_bytes(&self) -> usize {
        match self {
            BlockSolver::Instanced(s) => s.memory_bytes(),
            BlockSolver::Rtx(s) => s.memory_bytes(),
            BlockSolver::Sparse(s) => s.memory_bytes(),
        }
    }

    /// Structural invariants of the acceleration structures (tests).
    /// `xs_block` is the solver's exact value slice, needed to check the
    /// instanced lower-bound invariant.
    fn validate(&self, xs_block: &[f32]) -> Result<(), String> {
        match self {
            BlockSolver::Instanced(s) => s.validate(xs_block),
            BlockSolver::Rtx(s) => {
                let scene = s.scene();
                scene.bvh.validate(&scene.tris)?;
                if let Some(w) = &scene.wide {
                    w.validate(&scene.tris)?;
                }
                Ok(())
            }
            BlockSolver::Sparse(_) => Ok(()),
        }
    }
}

/// Snapshot of the work an update batch needs, taken by
/// [`ShardedRmq::stage_update_batch`] (cheap, lock-held): each touched
/// block's post-update value slice plus the decomposition fingerprint.
/// [`build`](Self::build) turns it into a [`PreparedBlockUpdate`] with
/// no lock held — the expensive half of the pipelined write path.
pub struct StagedUpdateSpec {
    n: usize,
    bs: usize,
    opts: ShardedOptions,
    /// Shared shape cache (Arc-cheap clone) so instanced replacement
    /// blocks build against the same trees with no lock held.
    shapes: ShapeSet,
    ops: Vec<UpdateOp>,
    /// (block id, fresh value slice) per touched block. Empty when the
    /// segment carries a range op: the lazy-tag application at commit
    /// is cheaper than copying block slices would be, so the spec stays
    /// pointer-sized and the work happens at the fence ("Lazy range
    /// tags", `rmq/mod.rs`).
    blocks: Vec<(usize, Vec<f32>)>,
    has_range: bool,
}

impl StagedUpdateSpec {
    /// Build a replacement solver per touched block (parallel over
    /// blocks, like construction) and its fresh leftmost argmin. Pure:
    /// reads only the staged copies, so it runs concurrently with
    /// queries against the live structure.
    pub fn build(mut self, workers: usize) -> PreparedBlockUpdate {
        // Injected staging failure: unwinds before any refit work; the
        // staging lane catches it and the fence falls back to the
        // direct update path (same values, answers unchanged).
        crate::util::faults::fire("stage.build");
        let (bs, opts) = (self.bs, self.opts);
        let shapes = &self.shapes;
        let built: Vec<Vec<(usize, BlockSolver, u32)>> =
            pool::map_chunks_mut(&mut self.blocks, workers, |_, slice| {
                slice
                    .iter()
                    .map(|(b, vals)| {
                        let solver = BlockSolver::build(vals, &opts, shapes);
                        let local = super::naive_rmq(vals, 0, vals.len() - 1);
                        (*b, solver, (b * bs + local) as u32)
                    })
                    .collect()
            });
        PreparedBlockUpdate {
            n: self.n,
            bs: self.bs,
            ops: self.ops,
            blocks: built.into_iter().flatten().collect(),
            has_range: self.has_range,
        }
    }
}

/// Prepared refit work for one update batch: per touched block a
/// replacement solver built from the staged values, plus the fresh
/// leftmost global argmin. Installed by
/// [`ShardedRmq::commit_prepared`]; valid only while the decomposition
/// it was staged against (and its values) stand — the engine layer
/// guards both with a (seq, shape) fingerprint.
pub struct PreparedBlockUpdate {
    n: usize,
    bs: usize,
    ops: Vec<UpdateOp>,
    blocks: Vec<(usize, BlockSolver, u32)>,
    has_range: bool,
}

impl PreparedBlockUpdate {
    /// The original update ops in stream order (the direct-apply
    /// fallback input when a commit-time conflict voids the prepared
    /// work).
    pub fn ops(&self) -> &[UpdateOp] {
        &self.ops
    }

    /// Number of blocks this preparation rebuilt (0 for a tag-only
    /// spec — range segments defer all work to the commit fence).
    pub fn touched_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Whether this preparation is a pointer-sized tag spec (carries a
    /// range op; no prebuilt blocks, the commit applies lazy tags).
    pub fn is_tag_only(&self) -> bool {
        self.has_range
    }
}

/// The two-level sharded solver.
pub struct ShardedRmq {
    xs: Vec<f32>,
    /// Elements per block (last block may be shorter).
    bs: usize,
    /// Number of blocks.
    nb: usize,
    blocks: Vec<BlockSolver>,
    /// Min value per block (the summary solver's input array).
    block_min: Vec<f32>,
    /// Leftmost *global* argmin index per block.
    block_argmin: Vec<u32>,
    /// Solver over `block_min`; `None` when there is a single block.
    summary: Option<BlockSolver>,
    /// Shared shape trees (instanced backend): at most three distinct
    /// lengths — full block, tail block, summary. Counted once in
    /// [`memory_bytes`](RmqSolver::memory_bytes) no matter how many
    /// thousand blocks instance each tree.
    shapes: ShapeSet,
    opts: ShardedOptions,
    /// Lifetime range-update counters (see [`RangeStats`]).
    range_stats: RangeStats,
}

impl ShardedRmq {
    /// Build with auto-tuned block size and default backend/layout.
    pub fn new_auto(xs: &[f32]) -> ShardedRmq {
        Self::with_options(xs, ShardedOptions::default())
    }

    pub fn with_options(xs: &[f32], opts: ShardedOptions) -> ShardedRmq {
        let n = xs.len();
        assert!(n > 0, "empty array");
        let bs = if opts.block_size == 0 { auto_block_size(n) } else { opts.block_size };
        assert!(bs > 0, "block size must be positive");
        assert!(
            opts.backend != ShardBackend::Rtx || bs <= 1 << 24,
            "shard block size {bs} exceeds the flat-geometry precision limit 2^24 \
             (paper §5.2) — pick a smaller --shard-block or the sparse backend"
        );
        assert!(
            opts.backend != ShardBackend::Instanced || bs <= MAX_INSTANCED_LEN,
            "shard block size {bs} exceeds the instanced u16-position limit 2^16 — \
             pick a smaller --shard-block or the rtx/sparse backend"
        );
        let nb = n.div_ceil(bs);
        let workers =
            if opts.build_workers == 0 { pool::default_workers() } else { opts.build_workers };

        // Pre-populate the shared shapes (full block, tail, summary)
        // before the parallel loops, which borrow the set immutably.
        let mut shapes = ShapeSet::default();
        if opts.backend == ShardBackend::Instanced {
            shapes.ensure(bs.min(n), SHAPE_LEAF_SIZE);
            shapes.ensure(n - (nb - 1) * bs, SHAPE_LEAF_SIZE);
            if nb > 1 && nb <= MAX_INSTANCED_LEN {
                shapes.ensure(nb, SHAPE_LEAF_SIZE);
            }
        }

        // Per-block solvers, built in parallel (each block is independent).
        let mut slots: Vec<Option<BlockSolver>> = (0..nb).map(|_| None).collect();
        {
            let shapes = &shapes;
            pool::for_each_chunk_mut(&mut slots, workers, |off, slice| {
                for (k, slot) in slice.iter_mut().enumerate() {
                    let b = off + k;
                    let start = b * bs;
                    let end = (start + bs).min(n);
                    *slot = Some(BlockSolver::build(&xs[start..end], &opts, shapes));
                }
            });
        }
        let blocks: Vec<BlockSolver> =
            slots.into_iter().map(|s| s.expect("block built")).collect();

        // Block minima + the summary solver above them. An instanced
        // decomposition with more blocks than u16 positions can address
        // falls back to a sparse summary (auto-tuned block sizes keep
        // nb ≤ 2^16 up to n = 2^28; explicit tiny blocks can exceed it).
        let mut block_min = Vec::with_capacity(nb);
        let mut block_argmin = Vec::with_capacity(nb);
        for b in 0..nb {
            let start = b * bs;
            let end = (start + bs).min(n);
            let arg = super::naive_rmq(xs, start, end - 1);
            block_min.push(xs[arg]);
            block_argmin.push(arg as u32);
        }
        let summary = (nb > 1).then(|| {
            if opts.backend == ShardBackend::Instanced && nb > MAX_INSTANCED_LEN {
                BlockSolver::Sparse(SparseTable::new(&block_min))
            } else {
                BlockSolver::build(&block_min, &opts, &shapes)
            }
        });

        ShardedRmq {
            xs: xs.to_vec(),
            bs,
            nb,
            blocks,
            block_min,
            block_argmin,
            summary,
            shapes,
            opts,
            range_stats: RangeStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.bs
    }

    pub fn num_blocks(&self) -> usize {
        self.nb
    }

    pub fn backend(&self) -> ShardBackend {
        self.opts.backend
    }

    #[inline]
    fn block_len(&self, b: usize) -> usize {
        (self.xs.len() - b * self.bs).min(self.bs)
    }

    /// One query with explicit traversal state and counters (hot path).
    /// At most three probes: ≤2 partial blocks + 1 summary range.
    pub fn rmq_counted(&self, l: u32, r: u32, scratch: &mut RtxScratch, c: &mut Counters) -> u32 {
        let (l, r) = (l as usize, r as usize);
        debug_assert!(l <= r && r < self.xs.len());
        let (bl, br) = (l / self.bs, r / self.bs);
        let base_l = bl * self.bs;
        let end_l = base_l + self.block_len(bl);
        if bl == br {
            // Entirely inside one block: a single small-range probe.
            let local = self.blocks[bl].rmq_local(
                &self.xs[base_l..end_l],
                (l - base_l) as u32,
                (r - base_l) as u32,
                scratch,
                c,
            );
            return (base_l + local as usize) as u32;
        }
        // Left partial block. Later candidates must beat it *strictly* —
        // their indices are larger, so ties keep the leftmost.
        let left_local = self.blocks[bl].rmq_local(
            &self.xs[base_l..end_l],
            (l - base_l) as u32,
            (self.block_len(bl) - 1) as u32,
            scratch,
            c,
        );
        let mut best = (base_l + left_local as usize) as u32;
        // Fully covered interior blocks: one probe of the summary array.
        if br - bl > 1 {
            let summary = self.summary.as_ref().expect("nb > 1 has a summary");
            let b = summary
                .rmq_local(&self.block_min, (bl + 1) as u32, (br - 1) as u32, scratch, c)
                as usize;
            let cand = self.block_argmin[b];
            if self.xs[cand as usize] < self.xs[best as usize] {
                best = cand;
            }
        }
        // Right partial block.
        let base_r = br * self.bs;
        let end_r = base_r + self.block_len(br);
        let right_local =
            self.blocks[br].rmq_local(&self.xs[base_r..end_r], 0, (r - base_r) as u32, scratch, c);
        let cand = (base_r + right_local as usize) as u32;
        if self.xs[cand as usize] < self.xs[best as usize] {
            best = cand;
        }
        best
    }

    /// Batch execution with counters (bench-harness entry point); the
    /// worker/scratch/sort structure is the shared
    /// [`batch_counted_impl`](super::rtx) driver. With `packet_width >
    /// 0` chunks run through the probe-decomposition packet driver
    /// instead — same answers, shared node fetches.
    pub fn batch_counted(&self, queries: &[Query], workers: usize) -> (Vec<u32>, Counters) {
        if self.opts.packet_width > 0 {
            return self.batch_counted_packet(queries, workers);
        }
        super::rtx::batch_counted_impl(
            queries,
            workers,
            self.opts.sort_queries,
            |l, r, scratch, c| self.rmq_counted(l, r, scratch, c),
        )
    }

    /// Packetized batch driver. Per worker chunk (in the same optional
    /// left-endpoint order as the scalar path):
    ///
    /// 1. decompose every query into its ≤3 probes — single/left
    ///    partial, covered-summary, right partial — exactly the scalar
    ///    [`rmq_counted`](Self::rmq_counted) decomposition;
    /// 2. stable-sort the block probes by block id, so consecutive
    ///    probes of one block form runs (queries are sorted by left
    ///    endpoint, so runs are long), and cut each run into packets of
    ///    `packet_width`; summary probes all target one solver and
    ///    packetize directly;
    /// 3. resolve each packet through the backend's shared-descent
    ///    entry, then combine candidates per query with the scalar
    ///    path's strict-`<` compares in the same left < interior <
    ///    right order.
    ///
    /// Probes carry no cross-probe state (unlike Blocks-mode carried
    /// hits), so regrouping them is exact: every probe returns its
    /// solver's scalar answer bit-for-bit, and the combination logic is
    /// shared with the scalar path.
    fn batch_counted_packet(&self, queries: &[Query], workers: usize) -> (Vec<u32>, Counters) {
        let width = self.opts.packet_width.max(1);
        let sort = self.opts.sort_queries;
        let mut out = vec![0u32; queries.len()];
        let per_worker: Vec<Counters> = pool::map_chunks_mut(&mut out, workers, |off, slice| {
            let mut scratch = RtxScratch::new();
            let mut c = Counters::default();
            let m = slice.len();
            let mut order: Vec<u32> = (0..m as u32).collect();
            if sort && m > 1 {
                order.sort_unstable_by_key(|&k| queries[off + k as usize].0);
            }
            // Decomposition. Block probes: (block, l_local, r_local,
            // slot, is_right); summary probes: (bl+1, br-1, slot).
            const RIGHT: u32 = 1;
            let mut bprobes: Vec<(u32, u32, u32, u32, u32)> = Vec::with_capacity(m * 2);
            let mut sprobes: Vec<(u32, u32, u32)> = Vec::new();
            for &k in &order {
                let (l, r) = queries[off + k as usize];
                let (l, r) = (l as usize, r as usize);
                let (bl, br) = (l / self.bs, r / self.bs);
                let base_l = bl * self.bs;
                if bl == br {
                    bprobes.push((bl as u32, (l - base_l) as u32, (r - base_l) as u32, k, 0));
                    continue;
                }
                bprobes.push((
                    bl as u32,
                    (l - base_l) as u32,
                    (self.block_len(bl) - 1) as u32,
                    k,
                    0,
                ));
                if br - bl > 1 {
                    sprobes.push(((bl + 1) as u32, (br - 1) as u32, k));
                }
                let base_r = br * self.bs;
                bprobes.push((br as u32, 0, (r - base_r) as u32, k, RIGHT));
            }
            // Consecutive same-block runs (stable: within a block the
            // left-endpoint order survives, keeping packets coherent).
            bprobes.sort_by_key(|p| p.0);
            // Per-slot candidates: the left/single probe always exists;
            // summary and right are optional (u32::MAX = absent).
            let mut left_cand = vec![0u32; m];
            let mut sum_cand = vec![u32::MAX; m];
            let mut right_cand = vec![u32::MAX; m];
            let mut ranges: Vec<Query> = Vec::with_capacity(width);
            let mut results: Vec<u32> = Vec::with_capacity(width);
            let mut i = 0usize;
            while i < bprobes.len() {
                let b = bprobes[i].0 as usize;
                let mut j = i;
                while j < bprobes.len() && bprobes[j].0 as usize == b {
                    j += 1;
                }
                let base = b * self.bs;
                let end = base + self.block_len(b);
                for group in bprobes[i..j].chunks(width) {
                    ranges.clear();
                    ranges.extend(group.iter().map(|&(_, l, r, _, _)| (l, r)));
                    results.clear();
                    results.resize(group.len(), 0);
                    self.blocks[b].rmq_local_packet(
                        &self.xs[base..end],
                        &ranges,
                        &mut results,
                        &mut scratch,
                        &mut c,
                    );
                    for (g, &local) in group.iter().zip(&results) {
                        let global = (base + local as usize) as u32;
                        if g.4 == RIGHT {
                            right_cand[g.3 as usize] = global;
                        } else {
                            left_cand[g.3 as usize] = global;
                        }
                    }
                }
                i = j;
            }
            if !sprobes.is_empty() {
                let summary = self.summary.as_ref().expect("nb > 1 has a summary");
                for group in sprobes.chunks(width) {
                    ranges.clear();
                    ranges.extend(group.iter().map(|&(a, b, _)| (a, b)));
                    results.clear();
                    results.resize(group.len(), 0);
                    summary.rmq_local_packet(
                        &self.block_min,
                        &ranges,
                        &mut results,
                        &mut scratch,
                        &mut c,
                    );
                    for (g, &b) in group.iter().zip(&results) {
                        sum_cand[g.2 as usize] = self.block_argmin[b as usize];
                    }
                }
            }
            // Combine: identical candidate order and strict compares as
            // the scalar path — left partial < interior < right partial.
            for k in 0..m {
                let mut best = left_cand[k];
                if sum_cand[k] != u32::MAX {
                    let cand = sum_cand[k];
                    if self.xs[cand as usize] < self.xs[best as usize] {
                        best = cand;
                    }
                }
                if right_cand[k] != u32::MAX {
                    let cand = right_cand[k];
                    if self.xs[cand as usize] < self.xs[best as usize] {
                        best = cand;
                    }
                }
                slice[k] = best;
            }
            c
        });
        let mut total = Counters::default();
        for c in &per_worker {
            total.add(c);
        }
        (out, total)
    }

    /// Point update: rewrite one value, refit the owning block and the
    /// summary. Prefer [`update_batch`](Self::update_batch) for more than
    /// a handful of updates — it refits each touched block only once.
    pub fn update(&mut self, i: usize, v: f32) {
        self.update_batch(&[(i, v)]);
    }

    /// Batched point updates with the default worker pool; see
    /// [`update_batch_with`](Self::update_batch_with).
    pub fn update_batch(&mut self, updates: &[(usize, f32)]) {
        self.update_batch_with(updates, pool::default_workers());
    }

    /// Batched point updates with explicit parallelism. Updates are
    /// grouped by block; each touched block re-shapes its triangles,
    /// refits its BVH once and rescans its minimum — that per-block work
    /// is independent across blocks and runs in parallel over `workers`
    /// (the write-path twin of the parallel build). The summary refit is
    /// the single join point, applied sequentially at the end, so the
    /// result is bit-identical for any worker count. Later updates to
    /// the same index win (applied in order).
    pub fn update_batch_with(&mut self, updates: &[(usize, f32)], workers: usize) {
        if updates.is_empty() {
            return;
        }
        let mut by_block: BTreeMap<usize, Vec<(usize, f32)>> = BTreeMap::new();
        for &(i, v) in updates {
            assert!(i < self.xs.len(), "update index {i} out of range");
            self.xs[i] = v;
            by_block.entry(i / self.bs).or_default().push((i % self.bs, v));
        }
        let fresh_argmins: Vec<Vec<(usize, u32)>> = {
            // Carve disjoint `&mut` views of the touched blocks (ids
            // arrive sorted from the BTreeMap, so a split_at_mut walk
            // suffices).
            let mut jobs: Vec<(usize, Vec<(usize, f32)>, &mut BlockSolver)> =
                Vec::with_capacity(by_block.len());
            let mut rest: &mut [BlockSolver] = &mut self.blocks;
            let mut consumed = 0usize;
            for (b, local) in by_block {
                let (_, tail) = rest.split_at_mut(b - consumed);
                let (head, tail) = tail.split_at_mut(1);
                jobs.push((b, local, &mut head[0]));
                consumed = b + 1;
                rest = tail;
            }
            let xs = &self.xs;
            let old_min = &self.block_min;
            let old_argmin = &self.block_argmin;
            let (bs, n) = (self.bs, self.xs.len());
            pool::map_chunks_mut(&mut jobs, workers, |_, slice| {
                let mut out = Vec::with_capacity(slice.len());
                for (b, local, solver) in slice.iter_mut() {
                    let start = *b * bs;
                    let end = (start + bs).min(n);
                    let arg = if local.len() == 1 {
                        // Single-update block: path-refit the block BVH
                        // (Θ(log B) vs the full sweep) and maintain the
                        // min table in O(1) — the Θ(B) rescan is only
                        // needed when the old argmin's value *rose*.
                        solver.update_point(local, &xs[start..end]);
                        let (j, v) = local[0];
                        let gi = start + j;
                        let oa = old_argmin[*b] as usize;
                        if gi == oa {
                            // The leftmost minimum moved in place; if it
                            // rose, some other element may now win.
                            if v <= old_min[*b] {
                                gi
                            } else {
                                super::naive_rmq(xs, start, end - 1)
                            }
                        } else if v < old_min[*b] || (v == old_min[*b] && gi < oa) {
                            gi
                        } else {
                            oa
                        }
                    } else {
                        solver.update(local, &xs[start..end]);
                        super::naive_rmq(xs, start, end - 1)
                    };
                    out.push((*b, arg as u32));
                }
                out
            })
        };
        // Join point: fold fresh block minima into the summary tables and
        // refit the summary solver once (block order, deterministic).
        let mut summary_updates: Vec<(usize, f32)> = Vec::new();
        for (b, arg) in fresh_argmins.into_iter().flatten() {
            self.block_argmin[b] = arg;
            let v = self.xs[arg as usize];
            if self.block_min[b] != v {
                self.block_min[b] = v;
                summary_updates.push((b, v));
            }
        }
        self.apply_summary_updates(summary_updates);
    }

    /// Range `add v` over the inclusive span `[l, r]` (elementwise f32,
    /// exactly as the naive oracle applies it).
    pub fn range_add(&mut self, l: usize, r: usize, v: f32) {
        self.range_update(l, r, false, v);
    }

    /// Range `assign v` over the inclusive span `[l, r]`.
    pub fn range_assign(&mut self, l: usize, r: usize, v: f32) {
        self.range_update(l, r, true, v);
    }

    /// Range update ("Lazy range tags", `rmq/mod.rs`): blocks fully
    /// inside the span take the lazy-tag path on the instanced backend —
    /// an `add` shifts the block's `v_lo` transform in place (no
    /// requantize, no node work) and an `assign` collapses it to a
    /// constant block — each counted in
    /// [`tag_hits`](RangeStats::tag_hits). The ≤2 partial boundary
    /// blocks, and every covered block of a non-instanced backend,
    /// resolve through the existing rebuild/refit machinery. The value
    /// array is always rewritten elementwise (it is the served truth and
    /// the exact-resolution source), and the summary refits from the
    /// changed block minima, reusing the single-min path refit when only
    /// one block's minimum moved.
    pub fn range_update(&mut self, l: usize, r: usize, assign: bool, v: f32) {
        assert!(l <= r && r < self.xs.len(), "range update ({l},{r}) out of range");
        self.range_stats.range_updates += 1;
        let (bl, br) = (l / self.bs, r / self.bs);
        let mut summary_updates: Vec<(usize, f32)> = Vec::new();
        for b in bl..=br {
            let start = b * self.bs;
            let end = start + self.block_len(b);
            let covered = l <= start && r >= end - 1;
            let arg = if covered && assign {
                for x in &mut self.xs[start..end] {
                    *x = v;
                }
                match &mut self.blocks[b] {
                    BlockSolver::Instanced(s) => {
                        s.apply_assign(v);
                        self.range_stats.tag_hits += 1;
                    }
                    solver => {
                        let local: Vec<(usize, f32)> = (0..end - start).map(|j| (j, v)).collect();
                        solver.update(&local, &self.xs[start..end]);
                    }
                }
                start // leftmost of an all-equal block
            } else if covered {
                // Even a pure shift can move the leftmost argmin — f32
                // rounding can merge neighbours into fresh ties — so the
                // min/argmin re-derivation fuses into the same pass that
                // writes the values.
                let (mut m, mut a) = (f32::INFINITY, start);
                for (j, x) in self.xs[start..end].iter_mut().enumerate() {
                    *x += v;
                    if *x < m {
                        m = *x;
                        a = start + j;
                    }
                }
                match &mut self.blocks[b] {
                    BlockSolver::Instanced(s) => {
                        s.apply_add(&self.xs[start..end], v);
                        self.range_stats.tag_hits += 1;
                    }
                    solver => {
                        let local: Vec<(usize, f32)> =
                            self.xs[start..end].iter().copied().enumerate().collect();
                        solver.update(&local, &self.xs[start..end]);
                    }
                }
                a
            } else {
                // Boundary block: subrange value write, then the
                // existing rebuild/requantize path and a block rescan.
                let (lo, hi) = (l.max(start), r.min(end - 1));
                let local: Vec<(usize, f32)> = (lo..=hi)
                    .map(|i| {
                        let x = if assign { v } else { self.xs[i] + v };
                        self.xs[i] = x;
                        (i - start, x)
                    })
                    .collect();
                self.blocks[b].update(&local, &self.xs[start..end]);
                super::naive_rmq(&self.xs, start, end - 1)
            };
            self.block_argmin[b] = arg as u32;
            let mv = self.xs[arg];
            if self.block_min[b] != mv {
                self.block_min[b] = mv;
                summary_updates.push((b, mv));
            }
        }
        self.apply_summary_updates(summary_updates);
    }

    /// Apply a fenced update segment in stream order: maximal runs of
    /// consecutive point writes batch through
    /// [`update_batch_with`](Self::update_batch_with) (parallel over
    /// blocks), each range op applies via
    /// [`range_update`](Self::range_update). Ops are never reordered or
    /// merged across a range op — f32 adds don't reassociate, so op
    /// order is part of the answer contract.
    pub fn apply_update_ops(&mut self, ops: &[UpdateOp], workers: usize) {
        let mut points: Vec<(usize, f32)> = Vec::new();
        let mut flush = |s: &mut Self, points: &mut Vec<(usize, f32)>| {
            if !points.is_empty() {
                s.update_batch_with(points, workers);
                points.clear();
            }
        };
        for op in ops {
            match *op {
                UpdateOp::Point { i, v } => points.push((i, v)),
                UpdateOp::RangeAdd { l, r, v } => {
                    flush(self, &mut points);
                    self.range_update(l, r, false, v);
                }
                UpdateOp::RangeAssign { l, r, v } => {
                    flush(self, &mut points);
                    self.range_update(l, r, true, v);
                }
            }
        }
        flush(self, &mut points);
    }

    /// Lifetime range-update counters of this decomposition.
    pub fn range_stats(&self) -> RangeStats {
        self.range_stats
    }

    /// Seed the lifetime counters from a predecessor structure — the
    /// engine layer calls this when a re-shard/install/recovery rebuild
    /// replaces the decomposition, so the served counters stay monotone
    /// across structure swaps.
    pub fn adopt_range_stats(&mut self, prior: RangeStats) {
        self.range_stats.range_updates += prior.range_updates;
        self.range_stats.tag_hits += prior.tag_hits;
    }

    /// Fold changed block minima into the summary solver: a single moved
    /// minimum re-shapes one summary triangle and refits its ancestor
    /// path (removing the Θ(n/B) per-batch term the cost model charges
    /// updates); multi-block changes take the full summary refit. Shared
    /// by the direct write path and [`commit_prepared`](Self::commit_prepared).
    fn apply_summary_updates(&mut self, summary_updates: Vec<(usize, f32)>) {
        if summary_updates.is_empty() {
            return;
        }
        if let Some(s) = &mut self.summary {
            if summary_updates.len() == 1 {
                s.update_point(&summary_updates, &self.block_min);
            } else {
                s.update(&summary_updates, &self.block_min);
            }
        }
    }

    /// Stage an update batch against the current values: copy each
    /// touched block's value slice with the updates applied (later
    /// duplicates win, as in the direct path). This is the cheap,
    /// snapshot-consistent half of the pipelined write path — callers
    /// run it under a read lock, then [`StagedUpdateSpec::build`] the
    /// expensive per-block replacement solvers with **no lock held**,
    /// and finally [`commit_prepared`](Self::commit_prepared) under the
    /// write lock at the fence.
    pub fn stage_update_batch(&self, updates: &[(usize, f32)]) -> StagedUpdateSpec {
        let ops: Vec<UpdateOp> =
            updates.iter().map(|&(i, v)| UpdateOp::Point { i, v }).collect();
        self.stage_update_ops(&ops)
    }

    /// Ops-aware staging: a pure-point segment stages per-block value
    /// copies as before; a segment carrying a range op stages a
    /// pointer-sized tag spec instead — no value copies, no off-lock
    /// build work — because the lazy-tag application at the commit fence
    /// is cheaper than the staging copy would be. Either way the spec is
    /// fingerprint-guarded like any commit, and a conflict feeds the
    /// same ops back through the direct path.
    pub fn stage_update_ops(&self, ops: &[UpdateOp]) -> StagedUpdateSpec {
        let has_range = ops.iter().any(|o| !matches!(o, UpdateOp::Point { .. }));
        let blocks = if has_range {
            Vec::new()
        } else {
            let mut by_block: BTreeMap<usize, Vec<(usize, f32)>> = BTreeMap::new();
            for op in ops {
                if let UpdateOp::Point { i, v } = *op {
                    assert!(i < self.xs.len(), "update index {i} out of range");
                    by_block.entry(i / self.bs).or_default().push((i % self.bs, v));
                }
            }
            by_block
                .into_iter()
                .map(|(b, local)| {
                    let start = b * self.bs;
                    let end = (start + self.bs).min(self.xs.len());
                    let mut vals = self.xs[start..end].to_vec();
                    for (j, v) in local {
                        vals[j] = v;
                    }
                    (b, vals)
                })
                .collect()
        };
        StagedUpdateSpec {
            n: self.xs.len(),
            bs: self.bs,
            opts: self.opts,
            shapes: self.shapes.clone(),
            ops: ops.to_vec(),
            blocks,
            has_range,
        }
    }

    /// `stage` + `build` in one call (solver-level convenience; the
    /// serving pipeline splits them around its read lock).
    pub fn prepare_update_batch(
        &self,
        updates: &[(usize, f32)],
        workers: usize,
    ) -> PreparedBlockUpdate {
        self.stage_update_batch(updates).build(workers)
    }

    /// Ops-aware `stage` + `build` (see [`stage_update_ops`](Self::stage_update_ops)).
    pub fn prepare_update_ops(&self, ops: &[UpdateOp], workers: usize) -> PreparedBlockUpdate {
        self.stage_update_ops(ops).build(workers)
    }

    /// Install a prepared batch. Fails (returning the preparation
    /// untouched, values unchanged) when the prepared work no longer
    /// matches this decomposition — the array was re-sharded or swapped
    /// since the stage. Detecting a *value* conflict (a different update
    /// batch landing in between) is the caller's job via its sequence
    /// check (`coordinator::engine::ShardedEngine::commit_prepared`);
    /// with both checks passed, the installed structure answers exactly
    /// like a direct [`update_batch_with`](Self::update_batch_with).
    pub fn commit_prepared(
        &mut self,
        p: PreparedBlockUpdate,
    ) -> Result<(), PreparedBlockUpdate> {
        if p.n != self.xs.len() || p.bs != self.bs {
            return Err(p);
        }
        if p.has_range {
            // Tag-heavy segments carry no prebuilt blocks: the lazy-tag
            // application *is* the commit (cheaper than the staging
            // copy would have been), under the same fingerprint guard.
            let PreparedBlockUpdate { ops, .. } = p;
            self.apply_update_ops(&ops, 1);
            return Ok(());
        }
        let PreparedBlockUpdate { ops, blocks, .. } = p;
        for op in &ops {
            if let UpdateOp::Point { i, v } = *op {
                self.xs[i] = v;
            }
        }
        let mut summary_updates: Vec<(usize, f32)> = Vec::new();
        for (b, solver, arg) in blocks {
            self.blocks[b] = solver;
            self.block_argmin[b] = arg;
            let v = self.xs[arg as usize];
            if self.block_min[b] != v {
                self.block_min[b] = v;
                summary_updates.push((b, v));
            }
        }
        self.apply_summary_updates(summary_updates);
        Ok(())
    }

    /// The served values — the snapshot source for background rebuilds
    /// of static engines (`coordinator::engine`): the sharded engine is
    /// the only structure that tracks updates in place, so its value
    /// array *is* the current truth.
    pub fn values(&self) -> &[f32] {
        &self.xs
    }

    /// Build-time options in effect (re-shard construction preserves
    /// backend/layout and swaps only the block size).
    pub fn options(&self) -> ShardedOptions {
        self.opts
    }

    /// The single re-shard construction path: rebuild the decomposition
    /// from a (values, options) snapshot at a new block size, preserving
    /// every other option. `coordinator::engine::ShardedEngine::reshard`
    /// calls this with a snapshot taken under its read lock so the
    /// (long) build runs without holding the lock, then installs the
    /// result seq-checked; [`reshard`](Self::reshard) is the owned-solver
    /// convenience over the same path.
    pub fn reshard_from(values: &[f32], opts: ShardedOptions, block_size: usize) -> ShardedRmq {
        Self::with_options(values, ShardedOptions { block_size, ..opts })
    }

    /// Re-shard an owned solver to a new block size (see
    /// [`reshard_from`](Self::reshard_from)).
    pub fn reshard(&self, block_size: usize) -> ShardedRmq {
        Self::reshard_from(&self.xs, self.opts, block_size)
    }

    /// Current value at an index (serving mutable arrays needs reads too).
    pub fn value_of(&self, idx: u32) -> f32 {
        self.xs[idx as usize]
    }

    /// Structural invariants of every block BVH and the summary BVH
    /// (used by the update-path tests after refits).
    pub fn validate(&self) -> Result<(), String> {
        for (b, s) in self.blocks.iter().enumerate() {
            let start = b * self.bs;
            let end = start + self.block_len(b);
            s.validate(&self.xs[start..end]).map_err(|e| format!("block {b}: {e}"))?;
        }
        if let Some(s) = &self.summary {
            s.validate(&self.block_min).map_err(|e| format!("summary: {e}"))?;
        }
        // The summary tables must mirror the value array.
        for b in 0..self.nb {
            let start = b * self.bs;
            let end = start + self.block_len(b);
            let arg = super::naive_rmq(&self.xs, start, end - 1);
            if self.block_argmin[b] as usize != arg || self.block_min[b] != self.xs[arg] {
                return Err(format!("block {b}: stale min table"));
            }
        }
        Ok(())
    }
}

impl RmqSolver for ShardedRmq {
    fn name(&self) -> &'static str {
        "SHARDED"
    }

    fn rmq(&self, l: u32, r: u32) -> u32 {
        let mut scratch = RtxScratch::new();
        let mut c = Counters::default();
        self.rmq_counted(l, r, &mut scratch, &mut c)
    }

    fn batch(&self, queries: &[Query], workers: usize) -> Vec<u32> {
        self.batch_counted(queries, workers).0
    }

    fn memory_bytes(&self) -> usize {
        // Every owned allocation: per-block solvers, the summary, the
        // shared shape trees (once, not per instance), the min tables,
        // and the value array — `xs` is load-bearing (instanced probes
        // resolve exact values from it), so truthful resident accounting
        // includes it.
        self.blocks.iter().map(|b| b.memory_bytes()).sum::<usize>()
            + self.summary.as_ref().map_or(0, |s| s.memory_bytes())
            + self.shapes.memory_bytes()
            + self.block_min.len() * 4
            + self.block_argmin.len() * 4
            + self.xs.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::naive_rmq;
    use crate::rmq::sparse_table::SparseTable;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    fn backends() -> [ShardedOptions; 4] {
        [
            ShardedOptions::default(), // instanced
            ShardedOptions { backend: ShardBackend::Rtx, ..Default::default() },
            ShardedOptions {
                backend: ShardBackend::Rtx,
                layout: AccelLayout::Binary,
                ..Default::default()
            },
            ShardedOptions { backend: ShardBackend::Sparse, ..Default::default() },
        ]
    }

    #[test]
    fn paper_example_all_backends() {
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        for base in backends() {
            for bs in 1..=8usize {
                let s = ShardedRmq::with_options(&xs, ShardedOptions { block_size: bs, ..base });
                for l in 0..7u32 {
                    for r in l..7u32 {
                        assert_eq!(
                            s.rmq(l, r) as usize,
                            naive_rmq(&xs, l as usize, r as usize),
                            "{:?} bs={bs} ({l},{r})",
                            base.backend
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn auto_block_size_is_sane() {
        assert_eq!(auto_block_size(1), 4);
        assert!(auto_block_size(1 << 12).is_power_of_two());
        assert_eq!(auto_block_size(1 << 12), 64);
        assert_eq!(auto_block_size(1 << 30), 1 << 12); // clamped
        let s = ShardedRmq::new_auto(&[1.0, 0.5]);
        assert_eq!(s.num_blocks(), 1);
        assert_eq!(s.rmq(0, 1), 1);
    }

    #[test]
    fn single_block_and_tiny_arrays() {
        for base in backends() {
            let one = ShardedRmq::with_options(&[0.3], ShardedOptions { block_size: 4, ..base });
            assert_eq!(one.rmq(0, 0), 0);
            assert_eq!(one.num_blocks(), 1);
            let two = ShardedRmq::with_options(&[0.7, 0.7], base);
            assert_eq!(two.rmq(0, 1), 0, "leftmost tie");
        }
    }

    #[test]
    fn matches_oracle_random_block_sizes() {
        check("sharded vs oracle", 40, |rng| {
            let xs = gen::f32_array(rng, 1..=1500);
            let n = xs.len();
            let bs = 1usize << rng.range(0, 8);
            let st = SparseTable::new(&xs);
            for base in backends() {
                let s = ShardedRmq::with_options(&xs, ShardedOptions { block_size: bs, ..base });
                for _ in 0..16 {
                    let (l, r) = gen::query(rng, n);
                    let (got, want) = (s.rmq(l as u32, r as u32), st.rmq(l as u32, r as u32));
                    if got != want {
                        return Err(format!(
                            "{:?} n={n} bs={bs} ({l},{r}): got {got} want {want}",
                            base.backend
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn leftmost_ties_across_probe_kinds() {
        // Duplicate-heavy arrays force ties between the left partial,
        // summary, and right partial candidates.
        check("sharded leftmost ties", 40, |rng| {
            let xs = gen::dup_array(rng, 4..=600, 2);
            let bs = 1usize << rng.range(1, 5);
            let s = ShardedRmq::with_options(
                &xs,
                ShardedOptions { block_size: bs, ..Default::default() },
            );
            for _ in 0..24 {
                let (l, r) = gen::query(rng, xs.len());
                let want = naive_rmq(&xs, l, r);
                let got = s.rmq(l as u32, r as u32) as usize;
                if got != want {
                    return Err(format!("bs={bs} ({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_counts_at_most_three_probes() {
        let mut rng = Rng::new(90);
        let xs = rng.uniform_f32_vec(1024);
        let s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 32, ..Default::default() },
        );
        let queries: Vec<Query> = (0..256)
            .map(|_| {
                let l = rng.range(0, 1023);
                (l as u32, rng.range(l, 1023) as u32)
            })
            .collect();
        let st = SparseTable::new(&xs);
        let (got, c) = s.batch_counted(&queries, 3);
        assert_eq!(got, st.batch(&queries, 1));
        assert!(c.rays >= 256 && c.rays <= 3 * 256, "rays = {}", c.rays);
    }

    #[test]
    fn sorted_chunks_change_nothing() {
        let mut rng = Rng::new(91);
        let xs = rng.uniform_f32_vec(777);
        let queries: Vec<Query> = (0..128)
            .map(|_| {
                let l = rng.range(0, 776);
                (l as u32, rng.range(l, 776) as u32)
            })
            .collect();
        let sorted = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, ..Default::default() },
        );
        let unsorted = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, sort_queries: false, ..Default::default() },
        );
        let (a, ca) = sorted.batch_counted(&queries, 3);
        let (b, cb) = unsorted.batch_counted(&queries, 3);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
    }

    #[test]
    fn packet_batches_match_scalar_all_backends() {
        // Probe regrouping must be invisible: every backend, width
        // {1, 4, 7, 8, 16}, sorted and unsorted chunks, tie-heavy
        // values — answers equal to the scalar batch bit-for-bit.
        check("sharded packet batch == scalar batch", 15, |rng| {
            let xs = gen::dup_array(rng, 16..=1200, 2);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 6);
            let queries: Vec<Query> = (0..96)
                .map(|_| {
                    let (l, r) = gen::query(rng, n);
                    (l as u32, r as u32)
                })
                .collect();
            for base in backends() {
                for sort_queries in [true, false] {
                    let opts = ShardedOptions { block_size: bs, sort_queries, ..base };
                    let scalar = ShardedRmq::with_options(&xs, opts);
                    let want = scalar.batch_counted(&queries, 2).0;
                    for packet_width in [1usize, 4, 7, 8, 16] {
                        let packed =
                            ShardedRmq::with_options(&xs, ShardedOptions { packet_width, ..opts });
                        let got = packed.batch_counted(&queries, 2).0;
                        if got != want {
                            return Err(format!(
                                "{:?} bs={bs} sort={sort_queries} width={packet_width}: mismatch",
                                base.backend
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packet_batches_amortize_node_fetches() {
        // Sorted small-range batches over the instanced backend: node
        // fetches per query strictly decrease as the packet widens.
        let xs = Rng::new(104).uniform_f32_vec(1 << 14);
        let queries: Vec<Query> = (0..512u32)
            .map(|i| {
                let l = i * 8;
                (l, l + 100)
            })
            .collect();
        let mut fetches = Vec::new();
        let mut answers: Option<Vec<u32>> = None;
        for packet_width in [0usize, 4, 8, 16] {
            let s = ShardedRmq::with_options(
                &xs,
                ShardedOptions { block_size: 128, packet_width, ..Default::default() },
            );
            let (got, c) = s.batch_counted(&queries, 1);
            match &answers {
                None => answers = Some(got),
                Some(w) => assert_eq!(w, &got, "width {packet_width} changed answers"),
            }
            fetches.push(c.node_fetches);
        }
        for w in 1..fetches.len() {
            assert!(
                fetches[w] < fetches[w - 1],
                "node fetches not strictly decreasing across widths: {fetches:?}"
            );
        }
    }

    #[test]
    fn parallel_build_matches_serial_build() {
        let xs = Rng::new(92).uniform_f32_vec(2048);
        let par = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, build_workers: 4, ..Default::default() },
        );
        let ser = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, build_workers: 1, ..Default::default() },
        );
        let mut rng = Rng::new(93);
        for _ in 0..200 {
            let l = rng.range(0, 2047);
            let r = rng.range(l, 2047);
            assert_eq!(par.rmq(l as u32, r as u32), ser.rmq(l as u32, r as u32));
        }
    }

    #[test]
    fn updates_keep_answers_exact() {
        check("sharded updates", 25, |rng| {
            let xs = gen::f32_array(rng, 8..=512);
            let n = xs.len();
            let bs = 1usize << rng.range(1, 5);
            for base in backends() {
                let mut s =
                    ShardedRmq::with_options(&xs, ShardedOptions { block_size: bs, ..base });
                let mut local = xs.clone();
                for _ in 0..6 {
                    let batch: Vec<(usize, f32)> =
                        (0..4).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
                    for &(i, v) in &batch {
                        local[i] = v;
                    }
                    s.update_batch(&batch);
                    for _ in 0..8 {
                        let (l, r) = gen::query(rng, n);
                        let want = naive_rmq(&local, l, r);
                        let got = s.rmq(l as u32, r as u32) as usize;
                        if got != want {
                            return Err(format!(
                                "{:?} bs={bs} post-update ({l},{r}): got {got} want {want}",
                                base.backend
                            ));
                        }
                    }
                }
                s.validate()?;
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_update_batch_matches_sequential() {
        // The per-block refits are independent; the summary join is
        // sequential — any worker count must produce the same structure.
        check("parallel updates", 20, |rng| {
            let xs = gen::f32_array(rng, 64..=2048);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 6);
            let opts = ShardedOptions { block_size: bs, ..Default::default() };
            let mut par = ShardedRmq::with_options(&xs, opts);
            let mut ser = ShardedRmq::with_options(&xs, opts);
            for _ in 0..4 {
                let count = rng.range(1, 64);
                let batch: Vec<(usize, f32)> =
                    (0..count).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
                par.update_batch_with(&batch, 4);
                ser.update_batch_with(&batch, 1);
                for _ in 0..12 {
                    let (l, r) = gen::query(rng, n);
                    let (a, b) = (par.rmq(l as u32, r as u32), ser.rmq(l as u32, r as u32));
                    if a != b {
                        return Err(format!("bs={bs} ({l},{r}): par {a} != ser {b}"));
                    }
                }
            }
            par.validate()?;
            ser.validate()
        });
    }

    #[test]
    fn bulk_load_touches_every_block_in_parallel() {
        // A full-array rewrite (the "bulk load" shape the ROADMAP calls
        // out) touches every block at once.
        let xs = Rng::new(95).uniform_f32_vec(1024);
        let mut s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 32, ..Default::default() },
        );
        let mut rng = Rng::new(96);
        let fresh: Vec<f32> = rng.uniform_f32_vec(1024);
        let batch: Vec<(usize, f32)> = fresh.iter().copied().enumerate().collect();
        s.update_batch_with(&batch, 4);
        s.validate().unwrap();
        for _ in 0..100 {
            let l = rng.range(0, 1023);
            let r = rng.range(l, 1023);
            assert_eq!(s.rmq(l as u32, r as u32) as usize, naive_rmq(&fresh, l, r));
        }
    }

    #[test]
    fn single_min_point_refit_equals_rebuild() {
        // The summary point-refit path (batches that move exactly one
        // block minimum) must leave the solver answer-identical to a
        // from-scratch rebuild — the refit-vs-rebuild pin.
        check("summary point refit vs rebuild", 20, |rng| {
            let xs = gen::f32_array(rng, 64..=1024);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 5);
            for base in backends() {
                let opts = ShardedOptions { block_size: bs, ..base };
                let mut s = ShardedRmq::with_options(&xs, opts);
                let mut local = xs.clone();
                for _ in 0..6 {
                    // All updates land in one block and strictly lower its
                    // minimum, so exactly one summary entry changes.
                    let b = rng.range(0, n.div_ceil(bs) - 1);
                    let start = b * bs;
                    let end = (start + bs).min(n);
                    let cur = local[naive_rmq(&local, start, end - 1)];
                    let batch: Vec<(usize, f32)> = (0..2)
                        .map(|_| (rng.range(start, end - 1), cur * rng.f32() * 0.9))
                        .collect();
                    for &(i, v) in &batch {
                        local[i] = v;
                    }
                    s.update_batch(&batch);
                    let rebuilt = ShardedRmq::with_options(&local, opts);
                    for _ in 0..10 {
                        let (l, r) = gen::query(rng, n);
                        let want = naive_rmq(&local, l, r);
                        let (got, fresh) =
                            (s.rmq(l as u32, r as u32) as usize, rebuilt.rmq(l as u32, r as u32) as usize);
                        if got != want || fresh != want {
                            return Err(format!(
                                "{:?} bs={bs} ({l},{r}): refit {got} rebuild {fresh} want {want}",
                                base.backend
                            ));
                        }
                    }
                }
                s.validate()?;
            }
            Ok(())
        });
    }

    #[test]
    fn snapshot_and_reshard_preserve_values_and_answers() {
        let mut rng = Rng::new(97);
        let xs = rng.uniform_f32_vec(1024);
        let mut s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, ..Default::default() },
        );
        let batch: Vec<(usize, f32)> = (0..32).map(|_| (rng.range(0, 1023), rng.f32())).collect();
        s.update_batch(&batch);
        let mut local = xs.clone();
        for &(i, v) in &batch {
            local[i] = v;
        }
        // The snapshot is the served truth.
        assert_eq!(s.values(), &local[..]);
        assert_eq!(s.options().block_size, 64);
        // Re-sharding from the snapshot keeps answers hit-identical.
        let resharded = s.reshard(16);
        assert_eq!(resharded.block_size(), 16);
        assert_eq!(resharded.backend(), s.backend());
        assert_eq!(resharded.values(), s.values());
        for _ in 0..200 {
            let l = rng.range(0, 1023);
            let r = rng.range(l, 1023);
            assert_eq!(
                resharded.rmq(l as u32, r as u32) as usize,
                naive_rmq(&local, l, r),
                "({l},{r})"
            );
        }
        resharded.validate().unwrap();
    }

    #[test]
    fn prepared_commit_matches_direct_apply() {
        // The pipelined write path (stage → build off-lock → commit)
        // must leave the solver answer-identical to the direct
        // update_batch_with path — the bit-identical-results invariant.
        check("prepared vs direct updates", 20, |rng| {
            let xs = gen::f32_array(rng, 32..=1024);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 6);
            for base in backends() {
                let opts = ShardedOptions { block_size: bs, ..base };
                let mut staged = ShardedRmq::with_options(&xs, opts);
                let mut direct = ShardedRmq::with_options(&xs, opts);
                for _ in 0..5 {
                    let count = rng.range(1, 24);
                    let batch: Vec<(usize, f32)> =
                        (0..count).map(|_| (rng.range(0, n - 1), rng.f32())).collect();
                    let prep = staged.prepare_update_batch(&batch, 3);
                    assert!(prep.touched_blocks() >= 1);
                    staged.commit_prepared(prep).map_err(|_| "commit refused".to_string())?;
                    direct.update_batch_with(&batch, 1);
                    if staged.values() != direct.values() {
                        return Err(format!("{:?} bs={bs}: values diverge", base.backend));
                    }
                    for _ in 0..12 {
                        let (l, r) = gen::query(rng, n);
                        let (a, b) =
                            (staged.rmq(l as u32, r as u32), direct.rmq(l as u32, r as u32));
                        if a != b {
                            return Err(format!(
                                "{:?} bs={bs} ({l},{r}): staged {a} != direct {b}",
                                base.backend
                            ));
                        }
                    }
                }
                staged.validate()?;
            }
            Ok(())
        });
    }

    #[test]
    fn commit_refuses_a_resharded_decomposition() {
        let xs = Rng::new(98).uniform_f32_vec(512);
        let s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, ..Default::default() },
        );
        let prep = s.prepare_update_batch(&[(10, -1.0), (300, -2.0)], 2);
        // The decomposition the work was staged against is gone.
        let mut resharded = s.reshard(16);
        let back = resharded.commit_prepared(prep).expect_err("shape mismatch must refuse");
        assert_eq!(
            back.ops(),
            &[UpdateOp::Point { i: 10, v: -1.0 }, UpdateOp::Point { i: 300, v: -2.0 }]
        );
        assert_eq!(resharded.value_of(10), xs[10], "refused commit changes nothing");
        // The returned preparation feeds the direct-apply fallback.
        let ops = back.ops().to_vec();
        resharded.apply_update_ops(&ops, 2);
        assert_eq!(resharded.value_of(10), -1.0);
        assert_eq!(resharded.rmq(0, 511), 300);
        resharded.validate().unwrap();
    }

    #[test]
    fn single_update_fast_path_keeps_min_tables_exact() {
        // One-point batches take the path-refit + O(1) min-maintenance
        // route; ties and a raised old argmin are the tricky cases, so
        // quantised values keep them frequent.
        check("single-update fast path", 25, |rng| {
            let xs: Vec<f32> =
                gen::f32_array(rng, 16..=512).iter().map(|v| (v * 8.0).floor() / 8.0).collect();
            let n = xs.len();
            let bs = 1usize << rng.range(2, 5);
            let mut s = ShardedRmq::with_options(
                &xs,
                ShardedOptions { block_size: bs, ..Default::default() },
            );
            let mut local = xs.clone();
            for _ in 0..30 {
                let i = rng.range(0, n - 1);
                // Mix raises, drops and exact ties with existing values.
                let v = match rng.range(0, 2) {
                    0 => (rng.f32() * 8.0).floor() / 8.0,
                    1 => local[rng.range(0, n - 1)],
                    _ => local[i] + 0.25,
                };
                local[i] = v;
                s.update_batch(&[(i, v)]);
                s.validate()?;
                for _ in 0..6 {
                    let (l, r) = gen::query(rng, n);
                    let want = naive_rmq(&local, l, r);
                    let got = s.rmq(l as u32, r as u32) as usize;
                    if got != want {
                        return Err(format!("bs={bs} ({l},{r}): got {got} want {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn duplicate_index_in_one_batch_last_wins() {
        let xs = vec![0.5f32; 10];
        let mut s =
            ShardedRmq::with_options(&xs, ShardedOptions { block_size: 4, ..Default::default() });
        s.update_batch(&[(3, 0.1), (3, 0.9), (7, 0.2)]);
        assert_eq!(s.rmq(0, 9), 7);
        assert_eq!(s.value_of(3), 0.9);
        s.validate().unwrap();
    }

    #[test]
    fn memory_accounts_blocks_and_summary() {
        let xs = Rng::new(94).uniform_f32_vec(4096);
        let inst = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, ..Default::default() },
        );
        assert_eq!(inst.num_blocks(), 64);
        // Instance tables + shapes + min tables + the value array.
        assert!(inst.memory_bytes() > 4096 * 4);
        let rtx = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, backend: ShardBackend::Rtx, ..Default::default() },
        );
        let sparse = ShardedRmq::with_options(
            &xs,
            ShardedOptions {
                block_size: 64,
                backend: ShardBackend::Sparse,
                ..Default::default()
            },
        );
        // The memory ordering the instancing PR establishes: shared
        // shapes + compressed leaves < per-block sparse tables <
        // per-block BVHs + triangles.
        assert!(inst.memory_bytes() < sparse.memory_bytes(), "instanced is smallest");
        assert!(sparse.memory_bytes() < rtx.memory_bytes(), "sparse beats per-block BVHs");
    }

    #[test]
    fn instanced_resident_bytes_at_least_4x_below_rtx() {
        // The PR's acceptance ratio, asserted at a CI-friendly scale
        // with the auto block size (the ratio only grows with n: shape
        // trees amortize further and per-block BVH overhead doesn't).
        let xs = Rng::new(99).uniform_f32_vec(1 << 16);
        let inst = ShardedRmq::new_auto(&xs);
        assert_eq!(inst.backend(), ShardBackend::Instanced);
        let rtx = ShardedRmq::with_options(
            &xs,
            ShardedOptions { backend: ShardBackend::Rtx, ..Default::default() },
        );
        let (i, r) = (inst.memory_bytes(), rtx.memory_bytes());
        assert!(
            i * 4 <= r,
            "instanced {i} bytes vs rtx {r} bytes — ratio {:.2} < 4",
            r as f64 / i as f64
        );
        // Equal answers at the lower footprint.
        let mut rng = Rng::new(100);
        for _ in 0..300 {
            let l = rng.range(0, (1 << 16) - 1);
            let q = rng.range(l, (1 << 16) - 1);
            assert_eq!(inst.rmq(l as u32, q as u32), rtx.rmq(l as u32, q as u32));
        }
    }

    #[test]
    fn instanced_shape_cache_holds_at_most_three_trees() {
        // 1000 elements / bs 64: full blocks (64), tail (40), summary
        // (16 blocks) — three distinct lengths, three shared trees.
        let xs = Rng::new(101).uniform_f32_vec(1000);
        let s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 64, ..Default::default() },
        );
        assert_eq!(s.num_blocks(), 16);
        assert!(s.shapes.num_shapes() <= 3, "shapes = {}", s.shapes.num_shapes());
        s.validate().unwrap();
    }

    #[test]
    fn instanced_tiny_blocks_fall_back_to_sparse_summary() {
        // More blocks than u16 positions can address: the per-block
        // level stays instanced, the summary falls back to sparse.
        let xs = Rng::new(102).uniform_f32_vec((1 << 17) + 7);
        let s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 1, ..Default::default() },
        );
        assert!(s.num_blocks() > MAX_INSTANCED_LEN);
        assert!(matches!(s.summary, Some(BlockSolver::Sparse(_))));
        let mut rng = Rng::new(103);
        let n = xs.len();
        for _ in 0..200 {
            let l = rng.range(0, n - 1);
            let r = rng.range(l, n - 1);
            assert_eq!(s.rmq(l as u32, r as u32) as usize, naive_rmq(&xs, l, r), "({l},{r})");
        }
    }

    #[test]
    fn instanced_refit_path_matches_fresh_rebuild() {
        // Point updates through the instance refit path (leaf-table
        // write + lane-min walk) vs a from-scratch decomposition.
        check("instanced refit vs rebuild", 20, |rng| {
            let xs = gen::f32_array(rng, 32..=800);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 6);
            let opts = ShardedOptions { block_size: bs, ..Default::default() };
            let mut s = ShardedRmq::with_options(&xs, opts);
            let mut local = xs.clone();
            for _ in 0..8 {
                let i = rng.range(0, n - 1);
                let v = rng.f32() * 2.0 - 0.5; // can drop below the block v_lo
                local[i] = v;
                s.update_batch(&[(i, v)]);
                s.validate()?;
                let rebuilt = ShardedRmq::with_options(&local, opts);
                for _ in 0..12 {
                    let (l, r) = gen::query(rng, n);
                    let want = naive_rmq(&local, l, r);
                    let (a, b) =
                        (s.rmq(l as u32, r as u32) as usize, rebuilt.rmq(l as u32, r as u32) as usize);
                    if a != want || b != want {
                        return Err(format!(
                            "bs={bs} ({l},{r}): refit {a} rebuild {b} want {want}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn range_updates_match_naive_oracle_all_backends() {
        // Mixed point/range streams vs the elementwise oracle: the
        // differential house rule, at the solver level. Tag path
        // (instanced), rebuild path (rtx/sparse), boundary seams and
        // tie-heavy values all in one property.
        check("range updates vs oracle", 20, |rng| {
            let xs = gen::dup_array(rng, 16..=700, 3);
            let n = xs.len();
            let bs = 1usize << rng.range(2, 6);
            for base in backends() {
                let mut s =
                    ShardedRmq::with_options(&xs, ShardedOptions { block_size: bs, ..base });
                let mut local = xs.clone();
                for _ in 0..8 {
                    let ops: Vec<UpdateOp> = (0..4)
                        .map(|_| {
                            let a = rng.range(0, n - 1);
                            match rng.range(0, 3) {
                                0 => UpdateOp::Point { i: a, v: rng.f32() },
                                1 => UpdateOp::RangeAdd {
                                    l: a,
                                    r: rng.range(a, n - 1),
                                    v: rng.f32() - 0.5,
                                },
                                _ => UpdateOp::RangeAssign {
                                    l: a,
                                    r: rng.range(a, n - 1),
                                    v: rng.f32(),
                                },
                            }
                        })
                        .collect();
                    for op in &ops {
                        op.apply_naive(&mut local);
                    }
                    s.apply_update_ops(&ops, 3);
                    if s.values() != &local[..] {
                        return Err(format!("{:?} bs={bs}: values diverge", base.backend));
                    }
                    for _ in 0..10 {
                        let (l, r) = gen::query(rng, n);
                        let want = naive_rmq(&local, l, r);
                        let got = s.rmq(l as u32, r as u32) as usize;
                        if got != want {
                            return Err(format!(
                                "{:?} bs={bs} ({l},{r}): got {got} want {want}",
                                base.backend
                            ));
                        }
                    }
                }
                s.validate()?;
            }
            Ok(())
        });
    }

    #[test]
    fn covered_add_takes_the_tag_path() {
        // A full-coverage add over the instanced backend must absorb
        // every interior block as a tag hit — the O(1)-per-block claim,
        // checked via the counter, not trusted.
        let xs = Rng::new(105).uniform_f32_vec(512);
        let mut s = ShardedRmq::with_options(
            &xs,
            ShardedOptions { block_size: 32, ..Default::default() },
        );
        assert_eq!(s.range_stats(), RangeStats::default());
        s.range_add(0, 511, 0.25); // covers all 16 blocks
        assert_eq!(s.range_stats(), RangeStats { range_updates: 1, tag_hits: 16 });
        s.range_assign(32, 95, -1.0); // covers blocks 1–2
        assert_eq!(s.range_stats(), RangeStats { range_updates: 2, tag_hits: 18 });
        s.range_add(40, 100, 0.5); // blocks 1 and 3 partial, block 2 covered
        assert_eq!(s.range_stats(), RangeStats { range_updates: 3, tag_hits: 19 });
        let mut local = xs.clone();
        for x in &mut local[0..512] {
            *x += 0.25;
        }
        for x in &mut local[32..=95] {
            *x = -1.0;
        }
        for x in &mut local[40..=100] {
            *x += 0.5;
        }
        assert_eq!(s.values(), &local[..]);
        s.validate().unwrap();
        // Counters survive a structure swap via adoption.
        let mut resharded = s.reshard(16);
        assert_eq!(resharded.range_stats(), RangeStats::default());
        resharded.adopt_range_stats(s.range_stats());
        assert_eq!(resharded.range_stats(), s.range_stats());
        assert_eq!(resharded.values(), s.values());
    }

    #[test]
    fn tag_only_stage_commits_like_direct_apply() {
        // A segment carrying a range op stages pointer-sized (no block
        // copies, no off-lock build) and the commit applies the tags —
        // answer-identical to the direct ops path on every backend.
        check("tag-only stage vs direct", 15, |rng| {
            let xs = gen::f32_array(rng, 64..=800);
            let n = xs.len();
            let bs = 1usize << rng.range(3, 6);
            for base in backends() {
                let opts = ShardedOptions { block_size: bs, ..base };
                let mut staged = ShardedRmq::with_options(&xs, opts);
                let mut direct = ShardedRmq::with_options(&xs, opts);
                for _ in 0..4 {
                    let a = rng.range(0, n - 1);
                    let ops = vec![
                        UpdateOp::Point { i: rng.range(0, n - 1), v: rng.f32() },
                        UpdateOp::RangeAdd { l: a, r: rng.range(a, n - 1), v: rng.f32() - 0.5 },
                        UpdateOp::Point { i: rng.range(0, n - 1), v: rng.f32() },
                    ];
                    let prep = staged.prepare_update_ops(&ops, 2);
                    assert!(prep.is_tag_only());
                    assert_eq!(prep.touched_blocks(), 0, "tag spec prebuilds nothing");
                    staged.commit_prepared(prep).map_err(|_| "commit refused".to_string())?;
                    direct.apply_update_ops(&ops, 1);
                    if staged.values() != direct.values() {
                        return Err(format!("{:?} bs={bs}: values diverge", base.backend));
                    }
                    for _ in 0..10 {
                        let (l, r) = gen::query(rng, n);
                        let (a, b) =
                            (staged.rmq(l as u32, r as u32), direct.rmq(l as u32, r as u32));
                        if a != b {
                            return Err(format!(
                                "{:?} bs={bs} ({l},{r}): staged {a} != direct {b}",
                                base.backend
                            ));
                        }
                    }
                }
                staged.validate()?;
            }
            Ok(())
        });
    }
}

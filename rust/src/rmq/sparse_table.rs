//! Sparse-table RMQ — the ⟨O(n log n) space, O(1) query⟩ classic. Serves
//! as the repo-wide correctness oracle and as the "block minimums" lookup
//! structure variant of the paper's §5.3 (the alternative the authors
//! compared against a second acceleration structure).

use super::{Query, RmqSolver};

/// Sparse table over f32 values with leftmost-min tie-break.
pub struct SparseTable {
    xs: Vec<f32>,
    /// levels[k][i] = leftmost argmin of xs[i .. i + 2^(k+1)) (level 0 is
    /// window size 2; windows of size 1 are the identity and not stored).
    levels: Vec<Vec<u32>>,
}

impl SparseTable {
    pub fn new(xs: &[f32]) -> SparseTable {
        assert!(!xs.is_empty(), "empty array");
        let n = xs.len();
        let max_k = if n <= 1 { 0 } else { usize::BITS as usize - 1 - (n.leading_zeros() as usize) };
        let mut levels: Vec<Vec<u32>> = Vec::with_capacity(max_k);
        for k in 1..=max_k {
            let width = 1usize << k;
            let half = width / 2;
            let count = n + 1 - width;
            let level = {
                let prev = levels.last();
                let mut level = Vec::with_capacity(count);
                for i in 0..count {
                    let a = match prev {
                        None => i as u32,
                        Some(p) => p[i],
                    };
                    let b = match prev {
                        None => (i + half) as u32,
                        Some(p) => p[i + half],
                    };
                    // Left block strictly precedes right block, so <=
                    // keeps the leftmost min.
                    level.push(if xs[a as usize] <= xs[b as usize] { a } else { b });
                }
                level
            };
            levels.push(level);
        }
        SparseTable { xs: xs.to_vec(), levels }
    }

    /// The underlying values (used by solvers that need them).
    pub fn values(&self) -> &[f32] {
        &self.xs
    }

    #[inline]
    fn query(&self, l: usize, r: usize) -> u32 {
        debug_assert!(l <= r && r < self.xs.len());
        if l == r {
            return l as u32;
        }
        let span = r - l + 1;
        let k = usize::BITS as usize - 1 - span.leading_zeros() as usize; // floor(log2)
        if k == 0 {
            // span == 1 handled above; unreachable
            return l as u32;
        }
        let level = &self.levels[k - 1];
        let a = level[l];
        let b = level[r + 1 - (1 << k)];
        // Equal values: the leftmost global min lies in the left window if
        // the min value occurs there at all, and `a` is then exactly it.
        if self.xs[a as usize] <= self.xs[b as usize] {
            a
        } else {
            b
        }
    }
}

impl RmqSolver for SparseTable {
    fn name(&self) -> &'static str {
        "SPARSE"
    }

    fn rmq(&self, l: u32, r: u32) -> u32 {
        self.query(l as usize, r as usize)
    }

    fn memory_bytes(&self) -> usize {
        self.levels.iter().map(|l| l.len() * 4).sum::<usize>()
    }
}

/// Convenience: answer a batch with a fresh sparse table (tests).
pub fn oracle_batch(xs: &[f32], queries: &[Query]) -> Vec<u32> {
    let st = SparseTable::new(xs);
    queries.iter().map(|&(l, r)| st.rmq(l, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmq::naive_rmq;
    use crate::util::proptest::{check, gen};

    #[test]
    fn paper_example() {
        // §2: X = [9,2,7,8,4,1,3], RMQ(2,6) = 5
        let xs = [9.0, 2.0, 7.0, 8.0, 4.0, 1.0, 3.0];
        let st = SparseTable::new(&xs);
        assert_eq!(st.rmq(2, 6), 5);
        assert_eq!(st.rmq(0, 6), 5);
        assert_eq!(st.rmq(0, 3), 1);
        assert_eq!(st.rmq(3, 3), 3);
    }

    #[test]
    fn exhaustive_small_n() {
        // Every (l, r) on every array of length 1..=32 with duplicates.
        let mut state = 7u64;
        for n in 1..=32usize {
            let xs: Vec<f32> = (0..n)
                .map(|_| (crate::util::rng::splitmix64(&mut state) % 5) as f32)
                .collect();
            let st = SparseTable::new(&xs);
            for l in 0..n {
                for r in l..n {
                    assert_eq!(
                        st.rmq(l as u32, r as u32) as usize,
                        naive_rmq(&xs, l, r),
                        "n={n} l={l} r={r} xs={xs:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn property_random_arrays() {
        check("sparse table matches naive", 150, |rng| {
            let xs = gen::f32_array(rng, 1..=2048);
            let st = SparseTable::new(&xs);
            for _ in 0..32 {
                let (l, r) = gen::query(rng, xs.len());
                let got = st.rmq(l as u32, r as u32) as usize;
                let want = naive_rmq(&xs, l, r);
                if got != want {
                    return Err(format!("n={} ({l},{r}): got {got} want {want}", xs.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_duplicate_heavy() {
        check("sparse table leftmost ties", 100, |rng| {
            let xs = gen::dup_array(rng, 1..=512, 3);
            let st = SparseTable::new(&xs);
            for _ in 0..16 {
                let (l, r) = gen::query(rng, xs.len());
                let got = st.rmq(l as u32, r as u32) as usize;
                let want = naive_rmq(&xs, l, r);
                if got != want {
                    return Err(format!("({l},{r}): got {got} want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_n_log_n_words() {
        let st = SparseTable::new(&vec![0.0f32; 1024]);
        // levels k=1..=10, level k has n+1-2^k entries * 4 bytes
        let expect: usize = (1..=10).map(|k| (1024 + 1 - (1 << k)) * 4).sum();
        assert_eq!(st.memory_bytes(), expect);
    }
}
